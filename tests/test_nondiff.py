"""Non-differentiable objectives (paper §3.3): metric correctness, that
MeZO actually optimizes them (backprop gets zero gradient), and the
registry-selectable objective surface (``Bundle.loss_fn(objective=...)``)
training under both estimators with a ledger round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import zo
from repro.core import MeZO, MeZOConfig, TrajectoryLedger
from repro.core.nondiff import negative_accuracy, token_f1
from repro.core.trajectory import replay
from repro.models import OBJECTIVES, bundle
from repro.models.config import ModelConfig
from repro.tree_utils import tree_max_abs_diff


def test_negative_accuracy():
    logits = jnp.asarray([[[2.0, 1.0], [0.0, 3.0]]])     # preds: 0, 1
    labels = jnp.asarray([[0, 0]])
    assert float(negative_accuracy(logits, labels)) == pytest.approx(-0.5)
    mask = jnp.asarray([[1.0, 0.0]])
    assert float(negative_accuracy(logits, labels, mask)) == pytest.approx(-1.0)


def _py_f1(pred, gold, pad=0):
    from collections import Counter
    p = [t for t in pred if t != pad]
    g = [t for t in gold if t != pad]
    common = Counter(p) & Counter(g)
    overlap = sum(common.values())
    if overlap == 0:
        return 0.0
    prec, rec = overlap / len(p), overlap / len(g)
    return 2 * prec * rec / (prec + rec)


@pytest.mark.parametrize("pred,gold", [
    ([1, 2, 3, 0], [1, 2, 3, 0]),
    ([1, 2, 0, 0], [3, 4, 0, 0]),
    ([1, 1, 2, 0], [1, 2, 2, 0]),        # multiset counting
    ([5, 0, 0, 0], [5, 6, 7, 8]),
    ([0, 0, 0, 0], [1, 2, 0, 0]),        # empty prediction
])
def test_token_f1_matches_python_reference(pred, gold):
    got = float(token_f1(jnp.asarray([pred]), jnp.asarray([gold])))
    want = _py_f1(pred, gold)
    assert got == pytest.approx(want, abs=1e-6), (pred, gold)


def test_backprop_gets_zero_gradient_mezo_does_not():
    """The defining property: d(accuracy)/dθ = 0 a.e., but the ZO estimate is
    informative."""
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (8,))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (64, 8))
    ys = (xs @ w_true > 0).astype(jnp.int32)

    def objective(p, batch):
        logits = xs @ p["w"]
        pred = (logits > 0).astype(jnp.int32)
        return -jnp.mean((pred == ys).astype(jnp.float32))

    p0 = {"w": jnp.zeros((8,)) + 0.01}
    g_bp = jax.grad(objective)(p0, None)
    assert float(jnp.max(jnp.abs(g_bp["w"]))) == 0.0     # backprop: useless

    opt = MeZO(MeZOConfig(lr=5e-2, eps=1e-1))
    state = opt.init(0)
    step = jax.jit(opt.step_fn(objective))
    p = p0
    for _ in range(400):
        p, state, m = step(p, state, None)
    final_acc = -float(objective(p, None))
    assert final_acc > 0.9, final_acc                    # MeZO: optimizes it


# --------------------------------------------------------------------------- #
# the registry objective surface: Bundle.loss_fn(objective=...)
# --------------------------------------------------------------------------- #
def _tiny():
    cfg = ModelConfig(name="nondiff-tiny", family="dense", n_layers=1,
                      d_model=32, n_heads=2, d_ff=64, vocab_size=16)
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    batch = b.make_batch(jax.random.PRNGKey(1), 4, 8)
    return b, params, batch


@pytest.mark.parametrize("estimator,lr", [("spsa", 3e-2), ("fzoo", 1e-1)])
def test_accuracy_objective_trains_via_registry(estimator, lr):
    """The full path a user takes (``--objective accuracy``): the registry
    loss under a real model forward, optimized by both estimators on the xla
    backend.  Accuracy starts near chance (1/16) and at least doubles."""
    b, params, batch = _tiny()
    loss_fn = b.loss_fn(objective="accuracy")
    opt = (zo.mezo(lr=lr, eps=1e-1) if estimator == "spsa"
           else zo.fzoo(lr=lr, eps=1e-1, batch_seeds=4))
    p, state = params, opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    first = None
    for _ in range(300):
        p, state, m = step(p, state, batch)
        if first is None:
            first = float(m["loss"])
    final = float(m["loss"])
    assert final < first, (first, final)          # -accuracy decreases
    # measured: spsa 0.016 -> 0.219, fzoo 0.031 -> 0.125 at these hps
    assert -final >= 2.0 * -first, (first, final)


def test_nondiff_objective_ledger_round_trips():
    """A run on the accuracy objective is seed-replayable like any other:
    the (seed, projected_grad) ledger reproduces the trained params."""
    b, params, batch = _tiny()
    loss_fn = b.loss_fn(objective="accuracy")
    opt = zo.mezo(lr=3e-2, eps=1e-1)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32",
                           backend=opt.backend_name)
    p, state = params, opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    for i in range(5):
        p, state, m = step(p, state, batch)
        led.append(i, float(m["projected_grad"]), float(m["lr"]))
    led2 = TrajectoryLedger.from_bytes(led.to_bytes())
    rec = replay(params, led2, zo.mezo(lr=3e-2, eps=1e-1))
    assert tree_max_abs_diff(rec, p) < 2e-6
    rec2 = replay(params, led2, zo.mezo(lr=3e-2, eps=1e-1))
    assert tree_max_abs_diff(rec, rec2) == 0.0


def test_f1_objective_is_registry_selectable():
    b, params, batch = _tiny()
    assert "f1" in OBJECTIVES
    loss_fn = b.loss_fn(objective="f1")
    v = float(loss_fn(params, batch))
    assert -1.0 <= v <= 0.0                       # -F1 ∈ [-1, 0]
    # one ZO step moves the params (the estimator sees a signal)
    opt = zo.mezo(lr=3e-2, eps=1e-1)
    p, _, _ = jax.jit(opt.step_fn(loss_fn))(params, opt.init(params, seed=0),
                                            batch)
    assert tree_max_abs_diff(p, params) > 0.0
    with pytest.raises(ValueError, match="objective"):
        b.loss_fn(objective="rouge")
