"""Cross-plan conformance for the execution engine (``repro.exec``).

The matrix: (estimator ∈ {spsa, n_spsa, fzoo}) × (backend ∈ {xla,
pallas-interpret}) × (plan ∈ {local, seed_parallel(1), seed_parallel(2),
async staleness-0, replay}), asserting

* ``seed_parallel(1)`` ≡ ``local`` BITWISE (the engine's one seed schedule
  degenerates to the facade's at one group);
* ``seed_parallel(2)`` ≈ interleaved n-SPSA at the same seeds (documented
  tolerance: evaluations at the step's center vs. interleaved);
* async staleness-0 ≡ seed_parallel at the same group count (documented
  tolerance: per-worker jits fuse differently than the one-step graph);
* a ledger written under ANY plan replays under the ledger-driven ``replay``
  plan (replay-vs-replay bitwise; replay-vs-live ≤ fp accumulation);
* mismatched plan coordinates refuse (``PlanMismatchError``) for both
  ledgers and checkpoints;
* the canonical ``step_key`` moved to ``repro.perturb.stream`` bitwise-intact.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as zexec
from repro import zo
from repro.core.trajectory import TrajectoryLedger, replay
from repro.exec import PlanMismatchError, StepProgram
from repro.tree_utils import tree_max_abs_diff

BACKENDS = ["xla", "pallas-interpret"]


def make_opt(estimator: str, backend: str, lr=1e-3, eps=1e-3):
    if estimator == "spsa":
        return zo.mezo(lr=lr, eps=eps, backend=backend)
    if estimator == "n_spsa":
        return zo.mezo(lr=lr, eps=eps, n=2, backend=backend)
    if estimator == "fzoo":
        return zo.fzoo(lr=lr, eps=eps, batch_seeds=3, backend=backend)
    raise ValueError(estimator)


@pytest.fixture()
def problem():
    t = jax.random.normal(jax.random.PRNGKey(0), (16,))

    def loss_fn(p, b):
        scale = 1.0 if b is None else jnp.mean(b)
        return 0.5 * scale * jnp.sum((p["w"] - t) ** 2)

    params = {"w": jnp.zeros((16,))}
    batch = jnp.linspace(0.5, 1.5, 8)
    return loss_fn, params, batch


def run_plan(opt, plan, loss_fn, params, batch, steps=4, seed=3,
             ledger=None):
    prog = StepProgram(opt, plan)
    state = prog.init(params, seed=seed)
    step = jax.jit(prog.step_fn(loss_fn))
    p = params
    for i in range(steps):
        p, state, m = step(p, state, batch)
        if ledger is not None:
            g = m.get("projected_grads")
            ledger.append(i, np.asarray(g) if g is not None
                          else float(m["projected_grad"]), float(m["lr"]))
    return p, prog


def ledger_for(prog, seed=3):
    meta = prog.meta
    return TrajectoryLedger(base_seed=seed, grad_dtype="float32",
                            backend=meta["perturb_backend"],
                            batch_seeds=meta["batch_seeds"],
                            exec_plan=meta["exec_plan"],
                            n_groups=meta["n_groups"])


# --------------------------------------------------------------------------- #
# seed_parallel(1) ≡ local, bitwise (the headline engine guarantee)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("estimator", ["spsa", "fzoo"])
def test_seed_parallel_1_bitwise_equals_local(problem, estimator, backend):
    loss_fn, params, batch = problem
    p_local, _ = run_plan(make_opt(estimator, backend), zexec.local(),
                          loss_fn, params, batch)
    p_sp1, _ = run_plan(make_opt(estimator, backend), zexec.seed_parallel(1),
                        loss_fn, params, batch)
    assert tree_max_abs_diff(p_local, p_sp1) == 0.0


def test_seed_parallel_1_bitwise_on_one_device_mesh(problem):
    """The acceptance form: jitted under an explicit 1-device mesh with the
    sharding rule engine, seed_parallel(1) still reproduces local's bits for
    spsa AND fzoo on the xla backend."""
    loss_fn, params, batch = problem
    mesh = jax.make_mesh((1,), ("data",))
    for estimator in ("spsa", "fzoo"):
        p_local, _ = run_plan(make_opt(estimator, "xla"), zexec.local(),
                              loss_fn, params, batch)
        prog = StepProgram(make_opt(estimator, "xla"),
                           zexec.seed_parallel(1, mesh=mesh))
        pshard, sshard, bshard = prog.shardings(params, batch)
        state = prog.init(params, seed=3)
        with mesh:
            step = jax.jit(prog.step_fn(loss_fn),
                           in_shardings=(pshard, sshard, bshard))
            p = jax.device_put(params, pshard)
            b = jax.device_put(batch, bshard)
            for _ in range(4):
                p, state, _ = step(p, state, b)
        assert tree_max_abs_diff(p_local, jax.device_get(p)) == 0.0, estimator


# --------------------------------------------------------------------------- #
# seed_parallel(2): semantics vs interleaved n-SPSA, sliced batches
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_seed_parallel_2_close_to_interleaved_nspsa(problem, backend):
    """Same seeds (fold(skey0, g)), same per-seed η/n — the only semantic
    difference is evaluations at the step's center vs. interleaved, which on
    a smooth problem is O(η·ε) per step."""
    loss_fn, params, _ = problem
    p_sp, _ = run_plan(make_opt("n_spsa", backend), zexec.seed_parallel(2),
                       loss_fn, params, None)
    p_seq, _ = run_plan(make_opt("n_spsa", backend), zexec.local(),
                        loss_fn, params, None)
    assert tree_max_abs_diff(p_sp, p_seq) < 1e-5


def test_seed_parallel_slices_batch(problem):
    """Group g must see only its slice: a batch whose slices scale the loss
    differently produces different g per group than the full batch would."""
    loss_fn, params, batch = problem
    prog = StepProgram(make_opt("spsa", "xla"), zexec.seed_parallel(2))
    state = prog.init(params, seed=3)
    _, _, m = jax.jit(prog.step_fn(loss_fn))(params, state, batch)
    g = np.asarray(m["projected_grads"])
    assert g.shape == (2,) and g[0] != g[1]


# --------------------------------------------------------------------------- #
# ledger round-trip: any plan -> replay
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("estimator", ["spsa", "n_spsa", "fzoo"])
@pytest.mark.parametrize("plan_name", ["local", "sp1", "sp2"])
def test_ledger_roundtrip(problem, estimator, backend, plan_name):
    loss_fn, params, batch = problem
    plan = {"local": zexec.local(), "sp1": zexec.seed_parallel(1),
            "sp2": zexec.seed_parallel(2)}[plan_name]
    if plan_name == "sp1" and estimator == "n_spsa":
        pytest.skip("n_spsa(2) needs n_groups in (1, 2); sp1 covers n=1 "
                    "estimators")
    opt = make_opt(estimator, backend)
    prog = StepProgram(opt, plan)
    led = ledger_for(prog)
    p_live, _ = run_plan(opt, plan, loss_fn, params, batch, ledger=led)
    # serialization round-trip (MZOL2/3/4 depending on the coordinates)
    led2 = TrajectoryLedger.from_bytes(led.to_bytes())
    assert (led2.n_groups, led2.batch_seeds) == (led.n_groups, led.batch_seeds)
    if led.n_groups > 1:          # MZOL4 serializes the plan kind too
        assert led2.exec_plan == led.exec_plan
    # replay under the ledger-driven plan (bare optimizer wrap)
    rec = replay(params, led2, make_opt(estimator, backend))
    assert tree_max_abs_diff(rec, p_live) < 2e-6
    # replay is deterministic: replay-vs-replay bitwise
    rec2 = replay(params, led2, make_opt(estimator, backend))
    assert tree_max_abs_diff(rec, rec2) == 0.0
    # replay through a program on the matching plan agrees bitwise
    rec3 = StepProgram(make_opt(estimator, backend), plan).replay(params, led2)
    assert tree_max_abs_diff(rec, rec3) == 0.0


def test_ledger_plan_mismatch_refuses(problem):
    loss_fn, params, batch = problem
    opt = make_opt("spsa", "xla")
    prog = StepProgram(opt, zexec.seed_parallel(2))
    led = ledger_for(prog)
    run_plan(opt, zexec.seed_parallel(2), loss_fn, params, batch, ledger=led)
    with pytest.raises(PlanMismatchError, match="n_groups=2"):
        StepProgram(make_opt("spsa", "xla"),
                    zexec.seed_parallel(3)).replay(params, led)
    with pytest.raises(PlanMismatchError):
        StepProgram(make_opt("spsa", "xla"), zexec.local()).replay(params, led)


# --------------------------------------------------------------------------- #
# async staleness-0 on the engine
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("estimator", ["spsa", "fzoo"])
def test_async_staleness0_matches_seed_parallel(problem, estimator):
    from repro.distributed.async_zo import (AsyncZOWorker,
                                            contributions_to_ledger)
    loss_fn, params, batch = problem
    n = 2
    ws = [AsyncZOWorker(w, n, params, loss_fn, make_opt(estimator, "xla"),
                        base_seed=3) for w in range(n)]

    def shard(w):
        per = batch.shape[0] // n
        return batch[w * per:(w + 1) * per]

    contribs = []
    for _ in range(4):
        cs = [w.produce(shard(w.w)) for w in ws]
        contribs += cs
        for w in ws:
            for cb in cs:
                w.consume(cb)
    # workers are bitwise-consistent with each other (same multiset applied
    # in the same order)
    assert tree_max_abs_diff(ws[0].params, ws[1].params) == 0.0
    # ... and agree with the seed-parallel step on the full batch (same
    # seeds, same coeffs; per-worker jits fuse differently -> fp tolerance).
    # One-step agreement is ~1e-8; fzoo's 1/σ step normalization is chaotic
    # in params, so the per-round fusion wobble amplifies multiplicatively
    # across rounds (the PR-3-documented fzoo amplification) — hence the
    # looser final-state bound for fzoo.
    p_sp, _ = run_plan(make_opt(estimator, "xla"), zexec.seed_parallel(n),
                       loss_fn, params, batch)
    assert tree_max_abs_diff(ws[0].params, p_sp) < \
        (1e-3 if estimator == "fzoo" else 1e-6)
    # the assembled contribution ledger replays under the engine — from a
    # default-constructed ledger (contributions_to_ledger stamps the async
    # plan's coordinates onto it)
    led = TrajectoryLedger(base_seed=3, grad_dtype="float32")
    recorded, skipped = contributions_to_ledger(led, contribs, n_workers=n)
    assert (recorded, skipped) == (4, 0)
    assert (led.n_groups, led.exec_plan) == (n, "async_worker")
    assert len(led) == 4
    # replay applies the RECORDED g floats, so no chaos amplification — only
    # the per-apply fusion wobble accumulates additively
    rec = replay(params, led, make_opt(estimator, "xla"))
    assert tree_max_abs_diff(rec, ws[0].params) < 5e-6


def test_async_order_invariance_on_engine(problem):
    """The engine port of the order-invariance property: applying the same
    multiset of contributions in different orders yields the same parameters
    up to fp commutation error."""
    from repro.distributed.async_zo import AsyncZOWorker
    loss_fn, params, _ = problem
    a = AsyncZOWorker(0, 2, params, loss_fn, make_opt("spsa", "xla"),
                      base_seed=2, max_staleness=10)
    b = AsyncZOWorker(1, 2, params, loss_fn, make_opt("spsa", "xla"),
                      base_seed=2, max_staleness=10)
    cs = [a.produce(None), b.produce(None), a.produce(None), b.produce(None)]
    for cb in cs:
        a.consume(cb)
    for cb in reversed(cs):
        b.consume(cb)
    assert tree_max_abs_diff(a.params, b.params) < 1e-6


# --------------------------------------------------------------------------- #
# checkpoint resume refusal (exec_plan / n_groups in ckpt meta)
# --------------------------------------------------------------------------- #
def test_checkpoint_resume_refuses_n_groups_mismatch(problem, tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import train

    loss_fn_, params, _ = problem

    def loss_fn(p, b):
        return loss_fn_(p, None)

    pipe = Pipeline(DataSpec("lm", batch=4, seq=4, vocab=11, seed=1))
    ck = CheckpointManager(str(tmp_path), interval=2)
    prog = StepProgram(make_opt("spsa", "xla"), zexec.seed_parallel(2))
    train(loss_fn, params, prog, pipe, total_steps=2, ckpt=ck, donate=False)
    with pytest.raises(PlanMismatchError, match="n_groups=2"):
        train(loss_fn, params,
              StepProgram(make_opt("spsa", "xla"), zexec.seed_parallel(3)),
              pipe, total_steps=4, ckpt=ck, donate=False)
    # matching plan resumes fine
    res = train(loss_fn, params, StepProgram(make_opt("spsa", "xla"),
                                             zexec.seed_parallel(2)),
                pipe, total_steps=4, ckpt=ck, donate=False)
    assert res.resumed_from == 2


def test_train_loop_end_to_end_seed_parallel_recovery(problem, tmp_path):
    """Crash-resume under the seed-parallel plan: ckpt + MZOL4 ledger tail
    rejoin matches the uninterrupted run."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import FailureInjector, train

    loss_fn_, params, _ = problem

    def loss_fn(p, b):
        return loss_fn_(p, jnp.mean(b["tokens"].astype(jnp.float32)))

    pipe = Pipeline(DataSpec("lm", batch=4, seq=4, vocab=11, seed=1))
    mk = lambda: StepProgram(make_opt("spsa", "xla"), zexec.seed_parallel(2))
    ref = train(loss_fn, params, mk(), pipe, total_steps=8, donate=False)
    ck = CheckpointManager(str(tmp_path), interval=3)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    with pytest.raises(RuntimeError, match="injected failure"):
        train(loss_fn, params, mk(), pipe, total_steps=8, ckpt=ck,
              ledger=led, injector=FailureInjector(fail_at_step=5),
              donate=False)
    saved = ck.load_ledger()
    assert saved is not None and saved.n_groups == 2
    led2 = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    res = train(loss_fn, params, mk(), pipe, total_steps=8, ckpt=ck,
                ledger=led2, donate=False)
    assert res.resumed_from == 5
    # the quadratic's projected grads are ~100× the LM fault-tolerance
    # test's, so the replay-vs-live fusion wobble lands proportionally higher
    assert tree_max_abs_diff(res.params, ref.params) < 1e-5


# --------------------------------------------------------------------------- #
# engine guardrails
# --------------------------------------------------------------------------- #
def test_local_facade_flattens_nested_stream_grads(problem):
    """n_seeds>1 × batch_seeds>1 must emit the ledger's flat
    (n_groups·batch_seeds,) record, not a 2-D array that append rejects."""
    loss_fn, params, batch = problem
    est = zo.estimators.fzoo(batch_seeds=3, eps=1e-3)._replace(n_seeds=2)
    opt = zo.ZOOptimizer(est, zo.transforms.scale_by_schedule(1e-3))
    state = opt.init(params, seed=3)
    _, _, m = jax.jit(opt.step_fn(loss_fn))(params, state, batch)
    assert m["projected_grads"].shape == (6,)
    led = TrajectoryLedger(base_seed=3, grad_dtype="float32",
                           batch_seeds=3, n_groups=2)
    led.append(0, np.asarray(m["projected_grads"]), float(m["lr"]))
    assert len(led) == 1


def test_seed_parallel_rejects_indivisible_batch(problem):
    """Trailing rows must never be silently dropped: an indivisible leading
    dim fails at trace time, not by training on truncated slices."""
    loss_fn, params, batch = problem        # leading dim 8
    prog = StepProgram(make_opt("spsa", "xla"), zexec.seed_parallel(3))
    state = prog.init(params, seed=3)
    with pytest.raises(ValueError, match="does not divide"):
        jax.jit(prog.step_fn(loss_fn))(params, state, batch)


def test_plan_rejects_incompatible_compositions():
    with pytest.raises(ValueError, match="n_seeds"):
        StepProgram(make_opt("n_spsa", "xla"), zexec.seed_parallel(3))
    with pytest.raises(ValueError, match="applier"):
        StepProgram(zo.mezo_adam(lr=1e-3), zexec.seed_parallel(2))
    with pytest.raises(ValueError, match="Definition 6"):
        StepProgram(zo.mezo_rescaled(lr=1e-3), zexec.seed_parallel(2))
    with pytest.raises(ValueError, match="local plan"):
        StepProgram(object(), zexec.seed_parallel(2))
    # a chain without scale_by_schedule records no η, so group replay could
    # not reconstruct the live coefficient — refused up front
    with pytest.raises(ValueError, match="scale_by_schedule"):
        StepProgram(zo.ZOOptimizer(zo.estimators.spsa(eps=1e-3)),
                    zexec.seed_parallel(2))


# --------------------------------------------------------------------------- #
# step_key canonicalization (satellite: one definition, bitwise-intact)
# --------------------------------------------------------------------------- #
def test_step_key_one_canonical_definition():
    from repro.core import perturb as core_perturb
    from repro.perturb import stream
    from repro.perturb import xla as perturb_xla
    assert core_perturb.step_key is stream.step_key
    assert perturb_xla.step_key is stream.step_key
    k = jax.random.PRNGKey(5)
    for t in (0, 1, 17):
        legacy = jax.random.fold_in(k, t)
        assert np.array_equal(np.asarray(stream.step_key(k, t)),
                              np.asarray(legacy))
        assert np.array_equal(np.asarray(stream.StreamRef.derive(k, t).key),
                              np.asarray(legacy))


def test_distributed_modules_route_through_backend_only():
    """The acceptance grep: no direct core.perturb imports and no raw
    perturb/update arithmetic outside the engine's shared write path."""
    from repro.distributed import async_zo, collectives
    for mod in (collectives, async_zo):
        src = inspect.getsource(mod)
        assert "core.perturb" not in src, mod.__name__
        assert "apply_rank1(" not in src, mod.__name__
        assert ".perturb(" not in src, mod.__name__
