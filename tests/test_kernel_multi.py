"""``zo_fused_multi`` — the one-pass multi-seed kernels and their consumers.

Contracts (all in the jitted-computation regime the repo's bitwise
guarantees are scoped to — each kernel wrapper is its own jitted entry
point; see ``kernel._pin`` for why a single fused surrounding graph is
excluded):

  * fan-out: ``zo_affine_multi`` slice j ≡ ``zo_affine(seeds[j], a[j], b[j])``
    bitwise, B ∈ {1, 3, 8} × {gaussian, rademacher} × {f32, bf16, f16};
  * chained: ``zo_affine_chain`` ≡ the sequential per-seed ``zo_affine``
    fold bitwise (the in-register dtype cast reproduces each launch's
    rounding boundary);
  * sqnorm: ``zo_sqnorm_2d`` ≡ the pure-jnp oracle bitwise, and ≈ the
    directly-summed ‖z‖² of the affine kernel's stream;
  * backend: ``affine_many`` ≡ the sequential ``apply_rank1`` fold bitwise
    on BOTH backends for every dist (incl. the two-pass sphere rescale),
    ``perturb_many`` with per-stream scales ≡ stacked singles (the
    antithetic SPSA fan-out), and the full B × dist × dtype matrix;
  * ledger: a pre-PR-shaped batched (seed, g, lr) entry replays through
    ``affine_many`` bitwise-equal to the pre-fusion sequential
    ``apply_rank1`` loop — existing MZOL artifacts reproduce unchanged;
  * engine: ``apply_group_updates`` (the flattened one-call write path)
    ≡ the per-group ``apply_group_update`` fold.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.zo_fused import multi as zo_multi
from repro.kernels.zo_fused import ref as zo_ref
from repro.perturb import StreamRef, get_backend
from repro.perturb import pallas as pallas_mod

BACKENDS = ["xla", "pallas"]
DISTS = ["gaussian", "rademacher", "sphere"]
KERNEL_DISTS = ["gaussian", "rademacher"]        # sphere = rescaled gaussian
DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16]
DTYPE_IDS = ["f32", "bf16", "f16"]


def leaf(dtype, shape=(300, 40)):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    return x.astype(dtype)


def tree_eq(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def mixed_tree():
    return {"w": leaf(jnp.float32),
            "b": jnp.ones((77,), jnp.bfloat16),
            "h": leaf(jnp.float16, (129,)),
            "n": jnp.arange(3)}                  # non-floating rides along


# --------------------------------------------------------------------------- #
# Fan-out kernel: one x read, B outputs, per-stream coefficients
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("dist", KERNEL_DISTS)
@pytest.mark.parametrize("B", [1, 3, 8])
def test_multi_fanout_bitwise_vs_singles(B, dist, dtype):
    x = leaf(dtype)
    seeds = jnp.arange(B, dtype=jnp.int32) * 7 + 11
    a = jnp.linspace(0.5, 1.5, B)
    b = jnp.linspace(-0.1, 0.1, B)
    out = pallas_mod.zo_affine_multi(x, seeds, a, b, interpret=True,
                                     dist=dist)
    assert out.shape == (B,) + x.shape and out.dtype == x.dtype
    for j in range(B):
        single = pallas_mod.zo_affine(x, int(seeds[j]), float(a[j]),
                                      float(b[j]), interpret=True, dist=dist)
        np.testing.assert_array_equal(np.asarray(out[j]), np.asarray(single))


def test_multi_fanout_matches_existing_batched_kernel():
    """Shared-coefficient fan-out must be bitwise the PR-3 batched kernel
    (same tile walk, same streams) — the generalization cannot move bits."""
    x = leaf(jnp.float32)
    seeds = jnp.asarray([5, 9, 123], jnp.int32)
    batched = pallas_mod.zo_affine_batched(x, seeds, 0.9, 0.05,
                                           interpret=True)
    multi = pallas_mod.zo_affine_multi(x, seeds, jnp.full((3,), 0.9),
                                       jnp.full((3,), 0.05), interpret=True)
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(multi))


# --------------------------------------------------------------------------- #
# Chain kernel: B affine folds per resident tile, one θ round-trip
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("dist", KERNEL_DISTS)
@pytest.mark.parametrize("B", [1, 4])
def test_chain_bitwise_vs_sequential_singles(B, dist, dtype):
    x = leaf(dtype)
    seeds = jnp.arange(B, dtype=jnp.int32) * 13 + 3
    a = jnp.linspace(0.9, 1.0, B)
    b = jnp.linspace(-0.02, 0.02, B)
    fused = pallas_mod.zo_affine_chain(x, seeds, a, b, interpret=True,
                                       dist=dist)
    seq = x
    for j in range(B):
        seq = pallas_mod.zo_affine(seq, int(seeds[j]), float(a[j]),
                                   float(b[j]), interpret=True, dist=dist)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))


def test_chain_matches_ref_oracle():
    x = leaf(jnp.float32, (100,))
    seeds = jnp.asarray([5, 9], jnp.int32)
    a = jnp.asarray([0.99, 1.0])
    b = jnp.asarray([-0.01, 0.02])
    got = pallas_mod.zo_affine_chain(x, seeds, a, b, interpret=True)
    want = jax.jit(zo_ref.zo_affine_chain_ref, static_argnames=("dist",))(
        x, seeds, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------- #
# Sphere pass 1: the in-kernel ‖z‖² accumulator
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("n", [5, 131072, 262161])
def test_sqnorm_kernel_matches_ref_bitwise(n):
    got = zo_multi.zo_sqnorm_2d(n, 42, interpret=True)
    want = zo_multi.zo_sqnorm_ref(n, 42)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sqnorm_measures_the_affine_kernel_stream():
    """Pass 1 must measure exactly the z that pass 2 applies: ‖z‖² from the
    sqnorm kernel ≈ the directly-summed squares of the affine kernel's pure-z
    output (same seed, same counter positions; summation order differs so
    this is a tolerance check — the bitwise contract is vs the oracle)."""
    n = 12345
    z = pallas_mod.zo_affine(jnp.zeros((n,)), 42, 0.0, 1.0, interpret=True)
    direct = float(jnp.sum(jnp.asarray(z, jnp.float32) ** 2))
    got = float(zo_multi.zo_sqnorm_2d(n, 42, interpret=True))
    np.testing.assert_allclose(got, direct, rtol=1e-5)


# --------------------------------------------------------------------------- #
# Backend contract: affine_many ≡ sequential apply_rank1 fold
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_affine_many_bitwise_vs_sequential_fold(backend, dist):
    be = get_backend(backend)
    params = mixed_tree()
    refs = [StreamRef.derive(jax.random.PRNGKey(5), 9, j) for j in range(4)]
    coeffs = [0.01, -0.02, 0.003, 0.3]
    decays = [0.001, 0.0, 0.0, 0.0]
    fused = be.affine_many(params, refs, coeffs, decays, dist=dist)
    seq = params
    for r, c, d in zip(refs, coeffs, decays):
        seq = be.apply_rank1(seq, r, c, d, dist=dist)
    tree_eq(fused, seq)


@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_perturb_many_per_stream_scales_bitwise(backend, dist):
    """The antithetic SPSA fan-out: perturb_many with (ε, −ε) per-stream
    scales ≡ two single perturbs, bitwise — the contract behind evaluating
    θ+εz and θ−εz from one generation pass."""
    be = get_backend(backend)
    params = mixed_tree()
    ref = StreamRef.derive(jax.random.PRNGKey(2), 1)
    pair = be.perturb_many(params, [ref, ref], (1e-3, -1e-3), dist=dist)
    tree_eq(jax.tree_util.tree_map(lambda s: s[0], pair),
            be.perturb(params, ref, 1e-3, dist=dist))
    tree_eq(jax.tree_util.tree_map(lambda s: s[1], pair),
            be.perturb(params, ref, -1e-3, dist=dist))


@pytest.mark.parametrize("dtype", DTYPES, ids=DTYPE_IDS)
@pytest.mark.parametrize("dist", DISTS)
@pytest.mark.parametrize("B", [1, 3, 8])
def test_pallas_perturb_many_full_matrix_bitwise(B, dist, dtype):
    """The acceptance matrix: batched generation ≡ stacked singles across
    B × dist × dtype on the pallas backend (sphere included — the rescale
    is per-stream identical because every stream shares the StreamRef-level
    norm pass of its own counter stream)."""
    be = get_backend("pallas")
    params = {"w": leaf(dtype), "v": leaf(dtype, (129,))}
    refs = [StreamRef.derive(jax.random.PRNGKey(0), 4, j) for j in range(B)]
    many = be.perturb_many(params, refs, 1e-3, dist=dist)
    for j, r in enumerate(refs):
        tree_eq(jax.tree_util.tree_map(lambda x: x[j], many),
                be.perturb(params, r, 1e-3, dist=dist))


def test_affine_many_validates_lengths():
    be = get_backend("xla")
    refs = [StreamRef.derive(jax.random.PRNGKey(0), 0, j) for j in range(2)]
    with pytest.raises(ValueError, match="affine_many"):
        be.affine_many(mixed_tree(), refs, [0.1], [0.0, 0.0])


# --------------------------------------------------------------------------- #
# Ledger: pre-PR batched entries replay through the fused path unchanged
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_ledger_entry_replays_prefusion_arithmetic(backend):
    """``apply_rank1_batch`` (the replay path for batched (seed, g, lr)
    entries) now routes through ``affine_many`` — its output must stay
    bitwise the pre-fusion sequential loop it replaced:

        for j: θ ← (1 − [j==0]·decay)·θ − (coeff_j / B)·z(fold(skey, j))

    so every MZOL ledger recorded before this PR reproduces the same
    parameters, with no header or stream-id change."""
    from repro.zo.updates import apply_rank1_batch
    be = get_backend(backend)
    params = mixed_tree()
    skey = jax.random.PRNGKey(17)
    coeff_vec = jnp.asarray([0.02, -0.01, 0.005])
    got = apply_rank1_batch(params, skey, coeff_vec, 0.001, backend=be)
    want = params
    for j in range(3):
        ref = StreamRef(jax.random.fold_in(skey, j))
        want = be.apply_rank1(want, ref, coeff_vec[j] / 3,
                              0.001 if j == 0 else 0.0)
    tree_eq(got, want)


# --------------------------------------------------------------------------- #
# Engine: the flattened one-call write path ≡ the per-group fold
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("batch_seeds", [1, 2])
@pytest.mark.parametrize("backend", BACKENDS)
def test_apply_group_updates_bitwise_vs_per_group_fold(backend, batch_seeds):
    from repro.exec.engine import apply_group_update, apply_group_updates
    be = get_backend(backend)
    params = mixed_tree()
    skey0 = jax.random.PRNGKey(23)
    n_groups = 3
    if batch_seeds == 1:
        coeffs = [0.01, -0.02, 0.003]
    else:
        coeffs = [jnp.asarray([0.01, 0.02]), jnp.asarray([-0.01, 0.0]),
                  jnp.asarray([0.005, -0.005])]
    fused = apply_group_updates(params, skey0, coeffs, 0.001, n_groups,
                                batch_seeds, "gaussian", be)
    seq = params
    for g in range(n_groups):
        seq = apply_group_update(seq, skey0, g, n_groups, coeffs[g],
                                 0.001 if g == 0 else 0.0, batch_seeds,
                                 "gaussian", be)
    tree_eq(fused, seq)
