"""Protocol conformance for the composable ZO API (repro.zo).

Every optimizer — composed (zo.mezo / zo.mezo_adam / zo.mezo_rescaled), the
deprecated shims (MeZO / MeZOAdam / MeZOVariant), and the backprop baseline
(Adam) — must speak the same protocol: ``init(params, *, seed)`` /
``step_fn(loss_fn)`` / ``restore(state, step)``.  Beyond conformance:

* checkpoint-resume step-counter correctness — the bug class the old
  ``opt_state._replace(step=...)`` hack in train/loop.py papered over;
* bitwise equivalence of the shims vs. their explicit compositions
  (the acceptance bar for the deprecation);
* transform-chain semantics (clip / schedule / weight decay / trace).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import zo
from repro.core import MeZO, MeZOAdam, MeZOConfig, MeZOAdamConfig
from repro.core.mezo_variants import MeZOVariant, MeZOVariantConfig
from repro.train.adam import Adam, AdamConfig
from repro.tree_utils import tree_max_abs_diff


def target_tree(key=jax.random.PRNGKey(0)):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (12,)),
            "b": jax.random.normal(k2, (3, 5))}


TARGET = target_tree()


def loss_fn(p, batch):
    return 0.5 * sum(jnp.sum((x - y) ** 2) for x, y in
                     zip(jax.tree_util.tree_leaves(p),
                         jax.tree_util.tree_leaves(TARGET)))


def start_params():
    return jax.tree_util.tree_map(jnp.ones_like, TARGET)


# One factory per optimizer family, all constructed the protocol way.
OPTIMIZERS = {
    "zo_mezo": lambda: zo.mezo(lr=1e-3, eps=1e-3, weight_decay=0.01),
    "zo_mezo_clip_sched": lambda: zo.mezo(
        lr=1e-3, eps=1e-3, clip_projected_grad=1.0, lr_schedule="linear",
        total_steps=100, warmup_steps=3),
    "zo_n_spsa": lambda: zo.mezo(lr=1e-3, eps=1e-3, n=3),
    "zo_one_point": lambda: zo.mezo(lr=2e-4, eps=1e-2, estimator="one_point"),
    "zo_fzoo": lambda: zo.fzoo(lr=2e-4, eps=1e-3, batch_seeds=3),
    "zo_mezo_adam": lambda: zo.mezo_adam(lr=1e-2, eps=1e-3, window=8),
    "zo_mezo_adam_mat": lambda: zo.mezo_adam(lr=1e-2, eps=1e-3,
                                             materialized=True),
    "zo_rescaled": lambda: zo.mezo_rescaled(lr=1e-3, eps=1e-3,
                                            d_source="param_norm"),
    "shim_mezo": lambda: MeZO(MeZOConfig(lr=1e-3, eps=1e-3)),
    "shim_mezo_adam": lambda: MeZOAdam(MeZOAdamConfig(lr=1e-2, eps=1e-3)),
    "shim_variant": lambda: MeZOVariant(MeZOVariantConfig(lr=1e-3, eps=1e-3)),
    "backprop_adam": lambda: Adam(AdamConfig(lr=1e-2, total_steps=100)),
}


@pytest.fixture(params=sorted(OPTIMIZERS), ids=sorted(OPTIMIZERS))
def optimizer(request):
    return OPTIMIZERS[request.param]()


# --------------------------------------------------------------------------- #
# Protocol conformance
# --------------------------------------------------------------------------- #
def test_protocol_init_step_restore_roundtrip(optimizer):
    """Uniform surface: init(params, seed=)/step_fn/restore, a step counter
    that counts, and restore() that realigns it without touching params."""
    assert isinstance(optimizer, zo.Optimizer)   # structural (Protocol) check
    params = start_params()
    state = optimizer.init(params, seed=0)
    assert int(state.step) == 0
    step = jax.jit(optimizer.step_fn(loss_fn))
    for k in range(3):
        params, state, metrics = step(params, state, None)
        assert int(state.step) == k + 1
        assert "loss" in metrics and "lr" in metrics
    restored = optimizer.restore(state, 11)
    assert int(restored.step) == 11
    # restore is bookkeeping only: everything else unchanged
    for a, b in zip(jax.tree_util.tree_leaves(state)[1:],
                    jax.tree_util.tree_leaves(restored)[1:]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # restored state still steps
    p2, s2, _ = step(params, restored, None)
    assert int(s2.step) == 12


def test_step_counter_drives_seed_and_lr():
    """Two states at different step counters must produce different
    perturbation seeds — the resume-correctness property."""
    opt = zo.mezo(lr=1e-3, eps=1e-3)
    params = start_params()
    step = jax.jit(opt.step_fn(loss_fn))
    s0 = opt.init(params, seed=0)
    p_a, _, m_a = step(params, s0, None)
    p_b, _, m_b = step(params, opt.restore(s0, 5), None)
    assert float(m_a["projected_grad"]) != float(m_b["projected_grad"])
    assert tree_max_abs_diff(p_a, p_b) > 0


# --------------------------------------------------------------------------- #
# Checkpoint-resume step-counter correctness (the old _replace bug class)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("make_opt,use_ledger", [
    (lambda: MeZO(MeZOConfig(lr=1e-3, eps=1e-3)), True),
    (lambda: zo.mezo(lr=1e-3, eps=1e-3), True),
    # Adam-preconditioned updates are not rank-1 in (g, lr), so its resume
    # path is the full state checkpoint (no scalar-ledger tail replay).
    (lambda: MeZOAdam(MeZOAdamConfig(lr=5e-3, eps=1e-3, window=8)), False),
], ids=["shim_mezo", "zo_mezo", "shim_mezo_adam"])
def test_crash_resume_realigns_step_counter(tmp_path, make_opt, use_ledger):
    """Resume via full ckpt (+ ledger tail for rank-1 optimizers) must leave
    the optimizer's step counter at the resume point (seed source + lr
    index), and the continued run must match an uninterrupted one."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core import TrajectoryLedger
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import FailureInjector, train

    pipe = Pipeline(DataSpec("lm", batch=2, seq=4, vocab=11, seed=1))

    def lm_loss(p, batch):
        del batch
        return loss_fn(p, None)

    T = 10
    params = start_params()
    ref = train(lm_loss, params, make_opt(), pipe, total_steps=T, donate=False)
    assert int(ref.opt_state.step) == T

    ck = CheckpointManager(str(tmp_path / "run"), interval=4)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32") if use_ledger else None
    with pytest.raises(RuntimeError, match="injected failure"):
        train(lm_loss, params, make_opt(), pipe, total_steps=T, ckpt=ck,
              ledger=led, injector=FailureInjector(fail_at_step=7),
              donate=False)

    led2 = TrajectoryLedger(base_seed=0, grad_dtype="float32") if use_ledger else None
    res = train(lm_loss, params, make_opt(), pipe, total_steps=T, ckpt=ck,
                ledger=led2, donate=False)
    # ledger resumes at the crash point; ckpt-only resumes at the last save
    assert res.resumed_from == (7 if use_ledger else 4)
    assert int(res.opt_state.step) == T           # counter realigned + run out
    assert tree_max_abs_diff(res.params, ref.params) < 1e-5


# --------------------------------------------------------------------------- #
# Shim vs. composition equivalence (the deprecation acceptance bar)
# --------------------------------------------------------------------------- #
def _run(opt, state, steps):
    p = start_params()
    step = jax.jit(opt.step_fn(loss_fn))
    for _ in range(steps):
        p, state, m = step(p, state, None)
    return p, m


def _assert_bitwise(pa, pb):
    for x, y in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_composed_mezo_bitwise_equals_shim_25_steps():
    """zo.mezo(...) and the MeZO shim must take bitwise-identical steps over
    >= 20 steps on a fixed seed (clip + schedule + weight decay engaged)."""
    cfg = dict(lr=1e-3, eps=1e-3, weight_decay=0.01, clip_projected_grad=2.0,
               lr_schedule="linear", total_steps=200, warmup_steps=5)
    shim = MeZO(MeZOConfig(**cfg))
    composed = zo.mezo(**cfg)
    pa, ma = _run(shim, shim.init(7), 25)
    pb, mb = _run(composed, composed.init(start_params(), seed=7), 25)
    _assert_bitwise(pa, pb)
    assert float(ma["projected_grad"]) == float(mb["projected_grad"])
    assert float(ma["lr"]) == float(mb["lr"])


@pytest.mark.parametrize("n", [1, 4], ids=["n1", "n4"])
def test_composed_nspsa_bitwise_equals_shim(n):
    shim = MeZO(MeZOConfig(lr=1e-3, eps=1e-3, n=n))
    composed = zo.mezo(lr=1e-3, eps=1e-3, n=n)
    pa, _ = _run(shim, shim.init(3), 20)
    pb, _ = _run(composed, composed.init(None, seed=3), 20)
    _assert_bitwise(pa, pb)


def test_composed_one_point_bitwise_equals_shim():
    shim = MeZO(MeZOConfig(lr=2e-4, eps=1e-2, estimator="one_point"))
    composed = zo.mezo(lr=2e-4, eps=1e-2, estimator="one_point")
    pa, _ = _run(shim, shim.init(5), 20)
    pb, _ = _run(composed, composed.init(None, seed=5), 20)
    _assert_bitwise(pa, pb)


@pytest.mark.parametrize("kw", [
    dict(materialized=False, window=16),
    dict(materialized=True),
    dict(materialized=False, window=16, momentum_only=True),
], ids=["ring", "materialized", "momentum"])
def test_mezo_adam_shim_matches_composition(kw):
    """Shim trajectories must match the composition within fp tolerance
    (they are bitwise today; the tolerance is the contract)."""
    shim = MeZOAdam(MeZOAdamConfig(lr=1e-2, eps=1e-3, beta2=0.95, **kw))
    composed = zo.mezo_adam(lr=1e-2, eps=1e-3, beta2=0.95, **kw)
    pa, _ = _run(shim, shim.init(start_params(), seed=9), 20)
    pb, _ = _run(composed, composed.init(start_params(), seed=9), 20)
    assert tree_max_abs_diff(pa, pb) < 1e-6


@pytest.mark.parametrize("modify_expectation", [False, True],
                         ids=["def6", "def7"])
def test_variant_shim_matches_composition(modify_expectation):
    shim = MeZOVariant(MeZOVariantConfig(
        lr=1e-3, eps=1e-3, d_source="param_norm",
        modify_expectation=modify_expectation))
    composed = zo.mezo_rescaled(lr=1e-3, eps=1e-3, d_source="param_norm",
                                modify_expectation=modify_expectation)
    pa, _ = _run(shim, shim.init(start_params(), seed=11), 20)
    pb, _ = _run(composed, composed.init(start_params(), seed=11), 20)
    assert tree_max_abs_diff(pa, pb) < 1e-6


# --------------------------------------------------------------------------- #
# Transform-chain semantics
# --------------------------------------------------------------------------- #
def test_clip_transform_bounds_ledger_scalar():
    explode = lambda p, b: 1e6 * jnp.sum(p["a"]) + 0.0 * jnp.sum(p["b"])
    opt = zo.ZOOptimizer(zo.estimators.spsa(eps=1e-3),
                         zo.chain(zo.transforms.clip_projected_grad(1.0),
                                  zo.transforms.scale_by_schedule(1e-3)))
    state = opt.init(None, seed=0)
    _, _, m = jax.jit(opt.step_fn(explode))(start_params(), state, None)
    assert abs(float(m["projected_grad"])) <= 1.0


def test_weight_decay_transform_decays_params():
    zero_loss = lambda p, b: 0.0 * sum(jnp.sum(x) for x in
                                       jax.tree_util.tree_leaves(p))
    opt = zo.ZOOptimizer(zo.estimators.spsa(eps=1e-3),
                         zo.chain(zo.transforms.scale_by_schedule(0.1),
                                  zo.transforms.add_weight_decay(0.5)))
    state = opt.init(None, seed=0)
    p1, _, _ = jax.jit(opt.step_fn(zero_loss))(start_params(), state, None)
    np.testing.assert_allclose(np.asarray(p1["a"]), 0.95 * np.ones(12),
                               rtol=1e-3)


def test_trace_momentum_descends():
    opt = zo.ZOOptimizer(zo.estimators.spsa(eps=1e-3),
                         zo.chain(zo.transforms.scale_by_schedule(5e-3),
                                  zo.transforms.trace(0.9, window=16)))
    params = start_params()
    state = opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    l0 = float(loss_fn(params, None))
    for _ in range(300):
        params, state, _ = step(params, state, None)
    assert float(loss_fn(params, None)) < 0.5 * l0


def test_applier_transform_rejects_interleaved_nspsa():
    with pytest.raises(ValueError, match="n-SPSA"):
        zo.ZOOptimizer(zo.estimators.n_spsa(4, eps=1e-3),
                       zo.chain(zo.transforms.scale_by_schedule(1e-3),
                                zo.transforms.scale_by_zo_adam()))


def test_applier_transform_rejects_scalar_weight_decay():
    """add_weight_decay's decay slot is bypassed by applier transforms; the
    facade must reject the silent-no-op combination."""
    with pytest.raises(ValueError, match="weight_decay"):
        zo.ZOOptimizer(zo.estimators.spsa(eps=1e-3),
                       zo.chain(zo.transforms.scale_by_schedule(1e-3),
                                zo.transforms.add_weight_decay(0.01),
                                zo.transforms.scale_by_zo_adam()))


def test_replay_update_rejects_applier_compositions():
    """A (seed, g, lr) triple cannot reconstruct an Adam-preconditioned step
    (it also depends on the g-history window): replay must refuse rather
    than silently misreconstruct."""
    opt = zo.mezo_adam(lr=1e-3, eps=1e-3)
    skey = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="ledger replay"):
        opt.replay_update(start_params(), skey, jnp.float32(0.5),
                          jnp.float32(1e-3))


def test_async_worker_rejects_stateful_estimator():
    from repro.distributed.async_zo import AsyncZOWorker
    with pytest.raises(ValueError, match="stateless"):
        AsyncZOWorker(0, 2, start_params(), loss_fn,
                      zo.mezo(lr=1e-3, eps=1e-2, estimator="one_point"))


def test_replay_and_async_reject_definition6_rescaled():
    """Definition-6 updates run along D·z; a (seed, g, lr) ledger entry (and
    the async wire format) can only reproduce plain rank-1 updates."""
    from repro.distributed.async_zo import AsyncZOWorker
    opt6 = zo.mezo_rescaled(lr=1e-3, eps=1e-3, d_source="param_norm")
    with pytest.raises(ValueError, match="Definition 6"):
        opt6.replay_update(start_params(), jax.random.PRNGKey(0),
                           jnp.float32(0.5), jnp.float32(1e-3))
    with pytest.raises(ValueError, match="Definition 6"):
        AsyncZOWorker(0, 2, start_params(), loss_fn, opt6)
    # Definition 7 (modify_expectation) updates along plain z: replayable.
    opt7 = zo.mezo_rescaled(lr=1e-3, eps=1e-3, d_source="param_norm",
                            modify_expectation=True)
    opt7.replay_update(start_params(), jax.random.PRNGKey(0),
                       jnp.float32(0.5), jnp.float32(1e-3))


def test_ledger_with_non_zo_optimizer_fails_clearly():
    from repro.core import TrajectoryLedger
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import train
    pipe = Pipeline(DataSpec("lm", batch=2, seq=4, vocab=11, seed=1))
    with pytest.raises(ValueError, match="ledger recording requires"):
        train(lambda p, b: loss_fn(p, None), start_params(),
              Adam(AdamConfig(lr=1e-3)), pipe, total_steps=2,
              ledger=TrajectoryLedger(base_seed=0), donate=False)


def test_replay_update_matches_live_step_arithmetic():
    """The protocol's replay_update applies the identical rank-1 arithmetic a
    live (center-perturb) step applies — the ledger-recovery invariant."""
    opt = zo.mezo(lr=1e-3, eps=1e-3, weight_decay=0.01)
    params = start_params()
    state = opt.init(params, seed=4)
    p1, _, m = jax.jit(opt.step_fn(loss_fn))(params, state, None)
    from repro.core.perturb import step_key
    skey = step_key(opt.init(params, seed=4).base_key, jnp.int32(0))
    p_replayed = opt.replay_update(params, skey, m["projected_grad"], m["lr"])
    assert tree_max_abs_diff(p1, p_replayed) < 1e-6


# --------------------------------------------------------------------------- #
# Perturbation-backend selection (repro.perturb)
# --------------------------------------------------------------------------- #
def test_pallas_backend_full_train_loop_tracks_xla():
    """zo.mezo(..., backend='pallas') runs the full training loop on CPU
    (kernel in interpret mode) and its per-step losses match the xla backend
    to fp tolerance: the two backends draw different-but-equal-law z, so with
    a small lr the loss trajectories stay within fp-accumulation distance."""
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import train

    pipe = Pipeline(DataSpec("lm", batch=2, seq=4, vocab=11, seed=1))

    def lm_loss(p, batch):
        del batch
        return loss_fn(p, None)

    losses = {}
    for backend in ("xla", "pallas"):
        opt = zo.mezo(lr=1e-4, eps=1e-3, backend=backend)
        assert opt.backend_name.partition("+z")[0] == backend
        res = train(lm_loss, start_params(), opt, pipe, total_steps=30,
                    log_every=1)
        losses[backend] = np.asarray([l for _, l in res.losses])
    np.testing.assert_allclose(losses["pallas"], losses["xla"], rtol=2e-2)
    # and it actually optimizes
    assert losses["pallas"][-1] < losses["pallas"][0]


def test_pallas_backend_crash_resume_roundtrip(tmp_path):
    """Same-backend restore/replay round-trip under pallas: full ckpt +
    ledger-tail recovery continues the run exactly as the uninterrupted one
    (the xla-backend guarantee, preserved per backend)."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core import TrajectoryLedger
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import FailureInjector, train

    pipe = Pipeline(DataSpec("lm", batch=2, seq=4, vocab=11, seed=1))

    def lm_loss(p, batch):
        del batch
        return loss_fn(p, None)

    T = 10
    make_opt = lambda: zo.mezo(lr=1e-3, eps=1e-3, backend="pallas")
    params = start_params()
    ref = train(lm_loss, params, make_opt(), pipe, total_steps=T, donate=False)

    ck = CheckpointManager(str(tmp_path / "run"), interval=4)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    with pytest.raises(RuntimeError, match="injected failure"):
        train(lm_loss, params, make_opt(), pipe, total_steps=T, ckpt=ck,
              ledger=led, injector=FailureInjector(fail_at_step=7),
              donate=False)
    assert ck.load_ledger().backend == make_opt().backend_name
    assert ck.restore_latest(params)["meta"]["perturb_backend"] == \
        make_opt().backend_name

    led2 = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    res = train(lm_loss, params, make_opt(), pipe, total_steps=T, ckpt=ck,
                ledger=led2, donate=False)
    assert res.resumed_from == 7
    assert int(res.opt_state.step) == T
    assert tree_max_abs_diff(res.params, ref.params) < 1e-5


@pytest.mark.parametrize("preset", ["mezo_adam", "mezo_rescaled"],
                         ids=["adam", "rescaled"])
def test_pallas_backend_composes_with_transform_stack(preset):
    """Every estimator × transform composition runs under the pallas backend
    (the point of the refactor): Adam's materializing applier path and the
    rescaled estimator's d⁻¹⊙z perturbation both route their z generation
    through the kernel."""
    if preset == "mezo_adam":
        opt = zo.mezo_adam(lr=5e-3, eps=1e-3, window=8, backend="pallas")
    else:
        opt = zo.mezo_rescaled(lr=1e-3, eps=1e-3, d_source="param_norm",
                               backend="pallas")
    params = start_params()
    state = opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    l0 = float(loss_fn(params, None))
    for _ in range(60):
        params, state, m = step(params, state, None)
    assert np.isfinite(float(m["loss"]))
    assert float(loss_fn(params, None)) < l0


def test_custom_estimator_plugs_in():
    """The extension point the redesign buys: a new estimator is one factory,
    not a new optimizer class.  Forward-difference two-point as a demo.
    Perturbation and update go through ONE resolved backend — mixing two
    backends' z streams in a single estimator would silently decorrelate the
    perturb and update directions."""
    def forward_diff(eps=1e-3, dist="gaussian"):
        from repro.perturb import StreamRef, get_backend
        be = get_backend(None)     # session default (REPRO_BACKEND-aware)

        def init(params, key):
            return ()

        def estimate(loss, params, batch, key, est_state):
            ref = StreamRef(key)
            l0 = loss(params, batch)
            lp = loss(be.perturb(params, ref, eps, dist), batch)
            g = (lp - l0) / eps
            return zo.ZOEstimate(
                projected_grad=g, loss=l0,
                apply_update=lambda c, d: be.apply_rank1(params, ref, c, d,
                                                         dist),
                restore=lambda: params, est_state=est_state, aux={})

        return zo.ZOEstimator(init=init, estimate=estimate, n_seeds=1,
                              eps=eps, dist=dist, name="forward_diff",
                              backend=be)

    opt = zo.ZOOptimizer(forward_diff(eps=1e-3),
                         zo.chain(zo.transforms.scale_by_schedule(2e-3)))
    params = start_params()
    state = opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    l0 = float(loss_fn(params, None))
    for _ in range(400):
        params, state, _ = step(params, state, None)
    assert float(loss_fn(params, None)) < 0.5 * l0
