"""FZOO batched-seed estimator (zo.fzoo) + batched ``perturb_many`` kernels.

Contracts:
  * batched z generation is bitwise-equal to stacked singles for
    B ∈ {1, 3, 8} across dtypes, on both backends (the perturb_many
    override contract; jitted computations — see kernel._pin for why eager
    is excluded);
  * fzoo with B == 1 reduces exactly to one-sided SPSA modulo the std
    normalizer (property-tested with hypothesis);
  * end-to-end on both backends: it descends, B rides checkpoint/ledger
    metadata (MZOL3), crash-resume recovers through ledger-tail replay, and
    scalar-ledger replay is deterministic (bitwise) and reproduces the live
    run to fp-accumulation tolerance;
  * guard rails: applier transforms refuse the per-seed g vector, mixed-B
    artifacts refuse to resume, mixed-backend replay refuses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import zo
from repro.core import TrajectoryLedger
from repro.core.perturb import step_key
from repro.core.trajectory import replay
from repro.perturb import StreamRef, get_backend
from repro.tree_utils import tree_max_abs_diff

BACKENDS = ["xla", "pallas"]


def target_tree():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {"a": jax.random.normal(k1, (12,)),
            "b": jax.random.normal(k2, (3, 5))}


TARGET = target_tree()


def loss_fn(p, batch):
    return 0.5 * sum(jnp.sum((x - y) ** 2) for x, y in
                     zip(jax.tree_util.tree_leaves(p),
                         jax.tree_util.tree_leaves(TARGET)))


def start_params():
    return jax.tree_util.tree_map(jnp.ones_like, TARGET)


# --------------------------------------------------------------------------- #
# perturb_many: batched == stacked singles, bitwise
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B", [1, 3, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16],
                         ids=["f32", "bf16", "f16"])
def test_perturb_many_bitwise_vs_stacked_singles(backend, B, dtype):
    be = get_backend(backend)
    params = {"w": jax.random.normal(jax.random.PRNGKey(1),
                                     (70, 33)).astype(dtype),
              "b": jnp.ones((31,), dtype)}
    refs = [StreamRef.derive(jax.random.PRNGKey(0), 4, j) for j in range(B)]
    many = be.perturb_many(params, refs, 1e-3)
    assert many["w"].shape == (B, 70, 33)
    for j, r in enumerate(refs):
        single = be.perturb(params, r, 1e-3)
        for a, b in zip(jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(lambda x: x[j], many)),
                jax.tree_util.tree_leaves(single)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_perturb_many_property_bitwise_hypothesis():
    """Property form of the contract: random seeds/steps/scales, both
    backends, batched == stacked singles bitwise."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16 - 1), step=st.integers(0, 1000),
           scale=st.sampled_from([1e-3, 1e-2, -2e-3]),
           B=st.sampled_from([1, 3, 8]),
           backend=st.sampled_from(BACKENDS))
    def check(seed, step, scale, B, backend):
        be = get_backend(backend)
        params = {"w": jax.random.normal(jax.random.PRNGKey(2), (40, 9))}
        refs = [StreamRef.derive(jax.random.PRNGKey(seed), step, j)
                for j in range(B)]
        many = be.perturb_many(params, refs, scale)
        for j, r in enumerate(refs):
            single = be.perturb(params, r, scale)
            np.testing.assert_array_equal(np.asarray(many["w"][j]),
                                          np.asarray(single["w"]))

    check()


def test_batched_kernel_matches_ref_oracle_bitwise():
    from repro.kernels.zo_fused import ref as zo_ref
    from repro.perturb import pallas as pm
    x = jax.random.normal(jax.random.PRNGKey(0), (33, 65))
    seeds = [5, 9, 123]
    got = pm.zo_affine_batched(x, jnp.asarray(seeds, jnp.int32), 0.9, 0.05,
                               interpret=True)
    want = zo_ref.zo_affine_batched_ref(x, seeds, 0.9, 0.05)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------- #
# B == 1 reduces to one-sided SPSA (modulo the std normalizer)
# --------------------------------------------------------------------------- #
def test_fzoo_b1_reduces_to_one_sided_spsa_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16 - 1),
           eps=st.sampled_from([1e-3, 1e-2]),
           backend=st.sampled_from(BACKENDS))
    def check(seed, eps, backend):
        lr = 1e-4
        be = get_backend(backend)
        params = start_params()
        opt = zo.fzoo(lr=lr, eps=eps, batch_seeds=1, backend=backend)
        state = opt.init(params, seed=seed)
        p1, _, m = jax.jit(opt.step_fn(loss_fn))(params, state, None)

        # one-sided SPSA by hand on the same (unfolded) step key
        skey = step_key(jax.random.PRNGKey(seed), jnp.int32(0))
        ref = StreamRef(skey)

        @jax.jit
        def manual(params):
            l0 = loss_fn(params, None)
            l1 = loss_fn(be.perturb(params, ref, eps), None)
            g = (l1 - l0) / eps
            return be.apply_rank1(params, ref, jnp.float32(lr) * g, 0.0), g

        p_manual, g_manual = manual(params)
        assert abs(float(m["projected_grad"]) - float(g_manual)) <= \
            1e-6 * max(1.0, abs(float(g_manual)))
        assert tree_max_abs_diff(p1, p_manual) < 1e-6

    check()


def test_fzoo_std_transform_is_noop_at_b1():
    t = zo.transforms.scale_by_fzoo_std()
    u = zo.Updates(g=jnp.float32(3.5))
    ctx = None  # B == 1 path never touches the ctx
    u2, _ = t.update(u, (), ctx)
    assert float(u2.g) == 3.5


def test_fzoo_std_transform_normalizes_vector():
    t = zo.transforms.scale_by_fzoo_std()
    g = jnp.asarray([1.0, 3.0, 5.0, 7.0], jnp.float32)
    ctx = zo.TransformCtx(step=jnp.int32(0), base_key=jax.random.PRNGKey(0),
                          key=jax.random.PRNGKey(0), seed_index=0, n_seeds=1,
                          eps=1e-3, dist="gaussian", restore=lambda: None)
    u2, _ = t.update(zo.Updates(g=g), (), ctx)
    sigma = float(jnp.std(g * 1e-3))
    np.testing.assert_allclose(np.asarray(u2.g), np.asarray(g) / sigma,
                               rtol=1e-6)


# --------------------------------------------------------------------------- #
# End-to-end per backend: descent, metadata, crash-resume, replay
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_fzoo_descends(backend):
    opt = zo.fzoo(lr=2e-4, eps=1e-3, batch_seeds=8, backend=backend)
    assert opt.batch_seeds == 8
    assert opt.backend_name.partition("+z")[0] == backend
    params = start_params()
    state = opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    l0 = float(loss_fn(params, None))
    for _ in range(80):
        params, state, m = step(params, state, None)
    assert m["projected_grads"].shape == (8,)
    assert np.isfinite(float(m["fzoo_loss_std"]))
    assert float(loss_fn(params, None)) < 0.5 * l0


@pytest.mark.parametrize("backend", BACKENDS)
def test_fzoo_crash_resume_and_replay(tmp_path, backend):
    """Full ckpt + MZOL3 ledger-tail recovery: the recovered parameters match
    the uninterrupted run at the crash step to ulp scale, the completed
    resumed run tracks the reference (fzoo's 1/σ step normalization amplifies
    ulp-level fp differences through continued live steps, hence the looser
    final tolerance), and replay itself is deterministic bitwise."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import FailureInjector, train

    pipe = Pipeline(DataSpec("lm", batch=2, seq=4, vocab=11, seed=1))
    lm_loss = lambda p, b: loss_fn(p, None)
    B, T = 8, 10
    make_opt = lambda: zo.fzoo(lr=2e-4, eps=1e-3, batch_seeds=B,
                               weight_decay=0.01, backend=backend)
    params = start_params()
    ref = train(lm_loss, params, make_opt(), pipe, total_steps=T,
                donate=False)
    ref7 = train(lm_loss, params, make_opt(), pipe, total_steps=7,
                 donate=False)

    ck = CheckpointManager(str(tmp_path / "run"), interval=4)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    with pytest.raises(RuntimeError, match="injected failure"):
        train(lm_loss, params, make_opt(), pipe, total_steps=T, ckpt=ck,
              ledger=led, injector=FailureInjector(fail_at_step=7),
              donate=False)
    saved = ck.load_ledger()
    assert saved.backend == make_opt().backend_name
    assert saved.backend.partition("+z")[0] == backend
    assert saved.batch_seeds == B
    meta = ck.restore_latest(params)["meta"]
    assert meta["perturb_backend"] == make_opt().backend_name
    assert meta["batch_seeds"] == B

    # recovery point: ckpt@4 + ledger tail -> params at step 7
    rec, rec_step = ck.recover_via_ledger(
        ck.restore_latest(params)["params"], 4, make_opt())
    assert rec_step == 7
    assert tree_max_abs_diff(rec, ref7.params) < 1e-6

    led2 = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    res = train(lm_loss, params, make_opt(), pipe, total_steps=T, ckpt=ck,
                ledger=led2, donate=False)
    assert res.resumed_from == 7
    assert int(res.opt_state.step) == T
    assert tree_max_abs_diff(res.params, ref.params) < 2e-3

    # scalar-ledger replay from scratch: deterministic bitwise, tracks live
    led3 = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    res2 = train(lm_loss, params, make_opt(), pipe, total_steps=T,
                 ledger=led3, donate=False)
    r1 = replay(params, led3, make_opt())
    r2 = replay(params, led3, make_opt())
    assert tree_max_abs_diff(r1, r2) == 0.0
    assert tree_max_abs_diff(res2.params, r1) < 1e-6


def test_fzoo_ledger_mzol3_roundtrip():
    led = TrajectoryLedger(base_seed=7, grad_dtype="float32",
                           backend="pallas")
    led.append(0, np.asarray([0.5, -1.5, 2.0], np.float32), 1e-3)
    led.append(1, np.asarray([0.25, 0.75, -0.5], np.float32), 1e-3)
    raw = led.to_bytes()
    assert raw[:6] == b"MZOL3\x00"
    led2 = TrajectoryLedger.from_bytes(raw)
    assert led2.batch_seeds == 3 and led2.backend == "pallas"
    assert led2.steps == [0, 1]
    assert led2.grads == led.grads
    # scalar ledgers keep serializing as MZOL2 (old readers unaffected)
    led_s = TrajectoryLedger(base_seed=7, grad_dtype="float32")
    led_s.append(0, 0.5, 1e-3)
    assert led_s.to_bytes()[:6] == b"MZOL2\x00"


def test_fzoo_ledger_refuses_mixed_batch_seeds():
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    led.append(0, np.asarray([0.5, 1.0], np.float32), 1e-3)
    with pytest.raises(ValueError, match="batch_seeds"):
        led.append(1, 0.5, 1e-3)


def test_fzoo_checkpoint_refuses_batch_seeds_mismatch(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import train

    pipe = Pipeline(DataSpec("lm", batch=2, seq=4, vocab=11, seed=1))
    lm_loss = lambda p, b: loss_fn(p, None)
    ck = CheckpointManager(str(tmp_path / "run"), interval=2)
    train(lm_loss, start_params(), zo.fzoo(lr=2e-4, eps=1e-3, batch_seeds=4),
          pipe, total_steps=4, ckpt=ck, donate=False)
    with pytest.raises(ValueError, match="batch_seeds"):
        train(lm_loss, start_params(),
              zo.fzoo(lr=2e-4, eps=1e-3, batch_seeds=8),
              pipe, total_steps=6, ckpt=ck, donate=False)


def test_replay_refuses_batch_seeds_mismatch():
    """A batched MZOL3 ledger replayed through a B=1 optimizer (or vice
    versa) must refuse: the per-step g shape and the seed fold schedule both
    differ, so the scalar path would misapply the updates."""
    opt_scalar = zo.mezo(lr=1e-3, eps=1e-3)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32",
                           backend=opt_scalar.backend_name)
    led.append(0, np.asarray([0.5, 1.0], np.float32), 1e-3)
    with pytest.raises(ValueError, match="batch_seeds"):
        replay(start_params(), led, opt_scalar)
    opt_batched = zo.fzoo(lr=1e-4, eps=1e-3, batch_seeds=4)
    led_s = TrajectoryLedger(base_seed=0, grad_dtype="float32",
                             backend=opt_batched.backend_name)
    led_s.append(0, 0.5, 1e-3)
    with pytest.raises(ValueError, match="batch_seeds"):
        replay(start_params(), led_s, opt_batched)


def test_fzoo_replay_refuses_backend_mismatch():
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32",
                           backend="pallas")
    led.append(0, np.asarray([0.5, 1.0], np.float32), 1e-3)
    from repro.perturb import BackendMismatchError
    with pytest.raises(BackendMismatchError, match="pallas"):
        replay(start_params(), led,
               zo.fzoo(lr=1e-4, eps=1e-3, batch_seeds=2, backend="xla"))


def test_fzoo_rejects_applier_transforms():
    with pytest.raises(ValueError, match="batch"):
        zo.ZOOptimizer(zo.estimators.fzoo(batch_seeds=4),
                       zo.chain(zo.transforms.scale_by_schedule(1e-3),
                                zo.transforms.scale_by_zo_adam()))


def test_fzoo_pallas_accepts_full_dist_matrix():
    # sphere joined the pallas matrix (kernel-fused two-pass rescale) —
    # every documented distribution must now compose on either backend
    zo.fzoo(batch_seeds=4, dist="sphere", backend="pallas")
    # rademacher is generated in-kernel (sign of one counter stream)
    zo.fzoo(batch_seeds=4, dist="rademacher", backend="pallas")


def test_fzoo_pallas_sphere_step_runs_and_replays():
    """A live fzoo step with dist='sphere' on pallas produces finite params
    and its ledger entry replays to the same parameters (the scalar-ledger
    invariant extends to the rescaled distribution; fp-accumulation
    tolerance as for the other dists — bitwise determinism is asserted
    replay-vs-replay elsewhere)."""
    opt = zo.fzoo(lr=1e-4, eps=1e-3, batch_seeds=2, dist="sphere",
                  backend="pallas")
    params = start_params()
    state = opt.init(params, seed=3)
    p1, _, m = opt.step_fn(loss_fn)(params, state, None)
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree_util.tree_leaves(p1))
    skey = step_key(state.base_key, jnp.int32(0))
    p_rep = opt.replay_update(params, skey, m["projected_grads"], m["lr"])
    assert tree_max_abs_diff(p1, p_rep) < 1e-6


def test_fzoo_forward_count_is_batched():
    """The whole point: B seed evaluations cost ONE vmapped forward (plus the
    center) — count loss_fn traces, not calls."""
    calls = {"n": 0}

    def counting_loss(p, batch):
        calls["n"] += 1
        return loss_fn(p, batch)

    opt = zo.fzoo(lr=1e-4, eps=1e-3, batch_seeds=8)
    params = start_params()
    state = opt.init(params, seed=0)
    jax.jit(opt.step_fn(counting_loss))(params, state, None)
    # tracing evaluates the loss twice: once under vmap (the B-batched
    # forward), once for the center — sequential would trace it per seed
    assert calls["n"] == 2
