"""End-to-end behaviour tests for the paper's system: MeZO fine-tuning
improves a prompt-task LM from zero-shot toward FT quality (the paper's
headline claims, CPU-scale), and the no-prompt ablation fails (App. A)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import MeZO, MeZOConfig
from repro.data.synthetic import PromptClassification
from repro.models import bundle, transformer
from repro.models.config import ModelConfig
from repro.train.adam import Adam, AdamConfig

CFG = ModelConfig(name="sys-lm", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  max_seq=64, dtype="float32")
BATCH = 32


@pytest.fixture(scope="module")
def setup():
    task = PromptClassification(vocab=CFG.vocab_size, n_classes=2, seed=0)
    b = bundle(CFG)
    params = b.init(jax.random.PRNGKey(0))
    def logits_fn(p, batch):
        return transformer.forward(CFG, p, tokens=batch["tokens"]).logits
    def acc(p, t=task):
        return t.eval_accuracy(CFG, logits_fn, p, jax.random.PRNGKey(9), 384)
    return task, b, params, acc


def _mezo_train(loss_fn, params, task, steps, lr=3e-4):
    params = jax.tree_util.tree_map(jnp.copy, params)  # fixture is shared;
    opt = MeZO(MeZOConfig(lr=lr, eps=1e-3))            # donation would kill it
    state = opt.init(0)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    for s in range(steps):
        params, state, _ = step(params, state, task.batch_for_step(s, BATCH))
    return params


def test_mezo_beats_zero_shot(setup):
    """Paper claim 2: MeZO significantly outperforms zero-shot."""
    task, b, params, acc = setup
    a0 = acc(params)
    p = _mezo_train(b.loss_fn(), params, task, steps=500)
    a1 = acc(p)
    assert a1 > a0 + 0.15, (a0, a1)
    assert a1 > 0.75, a1


def test_mezo_close_to_ft(setup):
    """Paper claim: MeZO within a few points of backprop FT (with far more,
    far cheaper steps)."""
    task, b, params, acc = setup
    p_mezo = _mezo_train(b.loss_fn(), params, task, steps=700)
    adam = Adam(AdamConfig(lr=5e-3, total_steps=50))
    p_ft = jax.tree_util.tree_map(jnp.copy, params)
    st = adam.init(p_ft)
    step = jax.jit(adam.step_fn(b.loss_fn()), donate_argnums=(0,))
    for s in range(50):
        p_ft, st, _ = step(p_ft, st, task.batch_for_step(s, BATCH))
    a_mezo, a_ft = acc(p_mezo), acc(p_ft)
    assert a_mezo > a_ft - 0.12, (a_mezo, a_ft)


def test_prompt_is_crucial(setup):
    """Paper App. A: MeZO fails WITHOUT the prompt formulation."""
    task, b, params, acc = setup
    task_np = PromptClassification(vocab=CFG.vocab_size, n_classes=2, seed=0,
                                   prompt=False)
    p_np = _mezo_train(b.loss_fn(), params, task_np, steps=500)
    def logits_fn(p, batch):
        return transformer.forward(CFG, p, tokens=batch["tokens"]).logits
    a_np = task_np.eval_accuracy(CFG, logits_fn, p_np, jax.random.PRNGKey(9), 384)
    p_prompt = _mezo_train(b.loss_fn(), params, task, steps=500)
    a_p = acc(p_prompt)
    assert a_p > a_np + 0.1, (a_p, a_np)
