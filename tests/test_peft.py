"""PEFT (LoRA / prefix) × MeZO compatibility (paper §3, App. E.5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MeZO, MeZOConfig
from repro.models import all_archs, bundle
from repro.models import peft, transformer
from repro.tree_utils import tree_max_abs_diff, tree_size


@pytest.fixture(scope="module")
def setup():
    cfg = all_archs()["qwen2-0.5b"].smoke_cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    batch = b.make_batch(jax.random.PRNGKey(1), batch=2, seq=16)
    return cfg, b, params, batch


def test_lora_zero_init_is_identity(setup):
    cfg, b, params, batch = setup
    lora = peft.init_lora(cfg, jax.random.PRNGKey(2))
    merged = peft.merge_lora(params, lora)
    assert tree_max_abs_diff(merged, params) == 0.0     # B zero-init


def test_lora_changes_loss_after_update(setup):
    cfg, b, params, batch = setup
    lora = peft.init_lora(cfg, jax.random.PRNGKey(2))
    loss_fn = peft.lora_loss_fn(cfg, params)
    l0 = float(loss_fn(lora, batch))
    base_loss = float(b.loss_fn()(params, batch))
    assert l0 == pytest.approx(base_loss, rel=1e-5)
    opt = MeZO(MeZOConfig(lr=1e-3, eps=1e-3))
    state = opt.init(0)
    step = jax.jit(opt.step_fn(loss_fn))
    lora2, state, m = step(lora, state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # only the LoRA tree changed; base params untouched by construction
    assert tree_max_abs_diff(lora2, lora) > 0


def test_lora_param_count_is_small(setup):
    cfg, b, params, batch = setup
    lora = peft.init_lora(cfg, jax.random.PRNGKey(2))
    assert tree_size(lora) < 0.1 * tree_size(params)


def test_prefix_real_activation_init(setup):
    cfg, b, params, batch = setup
    pre = peft.init_prefix_from_tokens(cfg, params, jax.random.PRNGKey(3), m=4)
    assert pre["pk"].shape == (cfg.n_layers, 4, cfg.kv_heads, cfg.hd)
    assert bool(jnp.all(jnp.isfinite(pre["pk"].astype(jnp.float32))))


def test_prefix_loss_and_mezo_step(setup):
    cfg, b, params, batch = setup
    pre = peft.init_prefix_from_tokens(cfg, params, jax.random.PRNGKey(3), m=4)
    loss_fn = peft.prefix_loss_fn(cfg, params)
    l0 = loss_fn(pre, batch)
    assert bool(jnp.isfinite(l0))
    opt = MeZO(MeZOConfig(lr=1e-3, eps=1e-1))   # paper's prefix ε
    state = opt.init(0)
    pre2, state, m = jax.jit(opt.step_fn(loss_fn))(pre, state, batch)
    assert bool(jnp.isfinite(m["loss"]))


def test_prefix_attends_from_all_positions(setup):
    """A prefix K/V pair must influence logits at the FIRST position too (the
    sentinel mask makes prefixes visible everywhere)."""
    cfg, b, params, batch = setup
    pre0 = peft.init_prefix(cfg, jax.random.PRNGKey(4), m=2)
    big = jax.tree_util.tree_map(lambda x: x * 50.0, pre0)
    l_small, _ = peft._forward_with_prefix(cfg, params, pre0, batch)
    l_big, _ = peft._forward_with_prefix(cfg, params, big, batch)
    first_tok_diff = float(jnp.max(jnp.abs(l_small[:, 0] - l_big[:, 0])))
    assert first_tok_diff > 1e-4
