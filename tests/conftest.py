import os

# Tests run on the single real CPU device; ONLY launch/dryrun.py forces the
# 512 placeholder devices (see the system design notes).  Multi-device tests
# spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
