"""Paged-KV serving: block pool, radix prefix cache, chunked batched
prefill, and the gather kernel.

The load-bearing contract is TOKEN IDENTITY: the engine's output ids with
the prefix cache enabled equal its output ids with the cache disabled (and,
for dense families, equal direct full-recompute greedy decoding) — reusing
cached prefix KV must be invisible in the sampled tokens, on the XLA gather
path AND the pallas paged-gather kernel.  Around that sit the pool/radix
invariants: refcounts balance after slots release, eviction only ever takes
unpinned LRU leaves, adapter scopes never share prefixes, overlong prompts
and pool exhaustion refuse loudly, and request lifecycle stamps are
monotone."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import all_archs, bundle
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged import (KVBlockPool, PoolExhaustedError, RadixCache,
                               bucket_for, pow2ceil, prefill_buckets)
from repro.serve.tenants import AdapterDelta

GATHER_IMPLS = ["xla", "pallas"]


@pytest.fixture(scope="module")
def dense_setup():
    cfg = all_archs()["qwen2-0.5b"].smoke_cfg
    return cfg, bundle(cfg).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_setup():
    cfg = all_archs()["granite-moe-3b-a800m"].smoke_cfg
    return cfg, bundle(cfg).init(jax.random.PRNGKey(0))


def greedy_reference(cfg, params, prompt_ids, n_new):
    from repro.models import transformer
    ids = list(prompt_ids)
    for _ in range(n_new):
        logits = transformer.forward(
            cfg, params, tokens=jnp.asarray([ids], jnp.int32)).logits
        ids.append(int(jnp.argmax(logits[0, -1, :cfg.vocab_size])))
    return ids[len(prompt_ids):]


def template_waves(tpl_len=40, n_waves=2, per_wave=2):
    """Waves of prompts sharing one template with fresh 1-token suffixes:
    wave 0 populates the radix cache, later waves should hit it."""
    tpl = [(7 * i) % 200 + 3 for i in range(tpl_len)]
    return [[tpl + [50 + 10 * w + i] for i in range(per_wave)]
            for w in range(n_waves)]


def run_waves(engine, waves, max_new=4, adapter=None, rid0=0):
    outs = []
    for w, wave in enumerate(waves):
        reqs = [Request(rid0 + 10 * w + i, p, max_new_tokens=max_new,
                        adapter=adapter) for i, p in enumerate(wave)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        outs.append([r.out_ids for r in reqs])
    return outs


# --------------------------------------------------------------------------- #
# Token identity
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("gather_impl", GATHER_IMPLS)
def test_paged_matches_reference(dense_setup, gather_impl):
    """Paged engine (multi-block prompts, prefix cache on) == direct
    full-recompute greedy decoding, under both gather implementations."""
    cfg, params = dense_setup
    engine = ServeEngine(cfg, params, slots=2, max_len=64,
                         gather_impl=gather_impl)
    assert engine.paged
    prompts = [[3, 5, 7, 9] * 5, [11, 13, 17] * 6]   # 20 and 18 tokens
    reqs = [Request(i, p, max_new_tokens=5) for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, p in zip(reqs, prompts):
        want = greedy_reference(cfg, params, p, 5)
        assert r.out_ids == want, (gather_impl, r.rid, r.out_ids, want)


@pytest.mark.parametrize("gather_impl", GATHER_IMPLS)
@pytest.mark.parametrize("setup", ["dense_setup", "moe_setup"])
def test_cache_on_off_token_identity(setup, gather_impl, request):
    """THE paged contract: on a shared-template workload the engine with the
    radix prefix cache produces exactly the tokens the cache-disabled engine
    does — and actually reuses prefix KV while doing so."""
    cfg, params = request.getfixturevalue(setup)
    waves = template_waves()
    outs = {}
    for pc in (True, False):
        eng = ServeEngine(cfg, params, slots=2, max_len=64,
                          prefix_cache=pc, gather_impl=gather_impl)
        outs[pc] = run_waves(eng, waves)
        if pc:
            st = eng.prefix_stats()
            assert st["prefix_hits"] >= 2, st
            assert (st["prefill_tokens_computed"]
                    < st["prefill_tokens_submitted"]), st
    assert outs[True] == outs[False], (setup, gather_impl)


def test_prefix_counters(dense_setup):
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, slots=2, max_len=64, block=16)
    waves = template_waves(tpl_len=40, n_waves=3, per_wave=2)
    run_waves(eng, waves)
    st = eng.prefix_stats()
    assert st["requests"] == 6
    assert st["prefill_tokens_submitted"] == 6 * 41
    # wave 0 is all-cold (both its requests are matched BEFORE either is
    # prefilled and inserted); the 4 requests of waves 1-2 each reuse the
    # template's 2 full blocks = 32 tokens (the radix match stops at 32 of
    # 40 template tokens — the last 8 sit in a partial block never cached)
    assert st["prefix_tokens_reused"] == 4 * 32
    assert (st["prefill_tokens_computed"]
            == st["prefill_tokens_submitted"] - st["prefix_tokens_reused"])
    assert st["prefix_hits"] == 4
    assert 0 < st["token_reuse_rate"] < 1
    assert st["prefix_hit_rate"] == pytest.approx(4 / 6)


def test_prefix_hit_prefills_only_suffix_batches(dense_setup):
    """A wave extending a cached prefix lands in the SMALL suffix bucket:
    the 41-token prompt would need the 64 bucket cold, but with 32 template
    tokens cached only a 16-wide suffix prefill runs."""
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    tpl = list(range(3, 43))
    run_waves(eng, [[tpl + [77]]])
    st0 = eng.prefix_stats()["prefill_tokens_computed"]
    run_waves(eng, [[tpl + [88]]], rid0=50)
    st1 = eng.prefix_stats()
    assert st1["prefill_tokens_computed"] - st0 == 41 - 32
    assert st1["prefix_hits"] == 1


# --------------------------------------------------------------------------- #
# Pool / radix invariants
# --------------------------------------------------------------------------- #
def test_slot_reuse_and_refcount_balance(dense_setup):
    """More requests than slots: slots recycle, and once everything drains
    the only refs left are the trash pin and the radix cache's own."""
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(i, [2 + i, 3 + i, 5 + i] * 6, max_new_tokens=3)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_ids) == 3 for r in reqs)
    assert all(t == [] for t in eng.tables)
    pool = eng.pool
    assert pool.refs[pool.trash] == 1
    # every non-trash ref is a radix pin; free list covers the rest
    assert sum(pool.refs[1:]) == eng.radix.n_nodes
    assert pool.n_free == pool.n_blocks - 1 - eng.radix.n_nodes


def test_eviction_under_pressure_spares_pinned_blocks(dense_setup):
    """Unit-level eviction contract: LRU unpinned leaves go first; a block
    some slot's table still holds (refs > 1) is never released even when it
    is the LRU leaf."""
    cfg, params = dense_setup
    pool = KVBlockPool(cfg, n_blocks=8, block=4, dtype=jnp.float32)
    radix = RadixCache(pool)
    b_a = pool.alloc(2)
    radix.insert(None, list(range(8)), b_a)          # chain a: 2 nodes
    b_b = pool.alloc(1)
    radix.insert(None, list(range(100, 104)), b_b)   # chain b: 1 node
    pool.ref(b_a[0])                                 # slot pins chain a's head
    for b in b_a + b_b:
        pool.unref(b)                                # slots dropped their refs
    # chain a's head is LRU but pinned; evict must take a's tail leaf and
    # chain b's leaf, then stop — the pinned head is not evictable
    assert radix.evict(3) == 2
    assert radix.n_nodes == 1
    assert pool.refs[b_a[0]] == 2                    # radix + slot pin intact
    pool.unref(b_a[0])
    assert radix.evict(1) == 1                       # now it can go
    assert pool.n_free == pool.n_blocks - 1


def test_engine_eviction_under_pool_pressure(dense_setup):
    """A pool too small for the accumulated radix cache: serving distinct
    prompts forces evictions (counted in stats) and still completes."""
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, slots=1, max_len=64,
                      pool_blocks=1 + 2 * 4)         # one slot's worth spare
    waves = [[[(i * 31 + j) % 200 + 3 for j in range(33)]] for i in range(4)]
    outs = run_waves(eng, waves, max_new=2)
    assert all(len(o[0]) == 2 for o in outs)
    assert eng.stats["evicted_blocks"] > 0
    for w, wave in enumerate(waves):                 # identity survives churn
        want = greedy_reference(cfg, params, wave[0], 2)
        assert outs[w][0] == want


def test_pool_exhaustion_refuses_loudly(dense_setup):
    """With the prefix cache off there is nothing to evict: a prompt needing
    more blocks than the pool holds raises instead of silently truncating."""
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, slots=1, max_len=64, pool_blocks=3,
                      prefix_cache=False)
    eng.submit(Request(0, list(range(3, 43)), max_new_tokens=2))
    with pytest.raises(PoolExhaustedError):
        eng.run()


def test_radix_scoped_per_adapter(dense_setup):
    """KV cached under one adapter identity is invisible to every other
    scope: the same template misses across base -> adapter-a -> adapter-b
    and hits only within a scope."""
    cfg, params = dense_setup
    leaves, treedef = jax.tree_util.tree_flatten(params)
    bumped = list(leaves)
    bumped[0] = leaves[0] + 0.25
    tuned = jax.tree_util.tree_unflatten(treedef, bumped)
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    eng.register_adapter("a", AdapterDelta.diff(params, tuned))
    tpl = list(range(3, 43))
    run_waves(eng, [[tpl + [50]]])                       # base, cold
    assert eng.stats["prefix_hits"] == 0
    run_waves(eng, [[tpl + [51]]], adapter="a", rid0=10)  # adapter, still cold
    assert eng.stats["prefix_hits"] == 0
    run_waves(eng, [[tpl + [52]]], adapter="a", rid0=20)  # adapter, warm
    assert eng.stats["prefix_hits"] == 1
    run_waves(eng, [[tpl + [53]]], rid0=30)               # base, warm
    assert eng.stats["prefix_hits"] == 2
    # re-registering different weights under the same name invalidates "a"
    rebumped = list(leaves)
    rebumped[0] = leaves[0] + 0.5
    eng.register_adapter(
        "a", AdapterDelta.diff(params,
                               jax.tree_util.tree_unflatten(treedef,
                                                            rebumped)))
    run_waves(eng, [[tpl + [54]]], adapter="a", rid0=40)
    assert eng.stats["prefix_hits"] == 2                  # cold again


def test_radix_match_always_leaves_suffix(dense_setup):
    """Even a prompt that is an exact cached-chunk multiple matches strictly
    short: prefill always has >= 1 real position to sample from."""
    cfg, params = dense_setup
    pool = KVBlockPool(cfg, n_blocks=6, block=4, dtype=jnp.float32)
    radix = RadixCache(pool)
    toks = list(range(12))
    radix.insert(None, toks, pool.alloc(3))
    blocks, end = radix.match(None, toks)
    assert end == 8 and len(blocks) == 2     # not 12: last chunk left over
    blocks, end = radix.match("other-scope", toks)
    assert (blocks, end) == ([], 0)


def test_request_times_monotonic(dense_setup):
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(i, [3 + i] * 10, max_new_tokens=3) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        t = r.times
        assert (t["queued"] <= t["prefill"] <= t["decode"] <= t["done"]), t


# --------------------------------------------------------------------------- #
# Buckets / limits
# --------------------------------------------------------------------------- #
def test_prefill_buckets_derived_from_limit():
    assert prefill_buckets(255) == (16, 32, 64, 128, 256)
    assert prefill_buckets(64) == (16, 32, 64)
    assert bucket_for(65, prefill_buckets(255)) == 128
    with pytest.raises(ValueError):
        bucket_for(257, prefill_buckets(255))
    assert pow2ceil(1) == 1 and pow2ceil(65) == 128


def test_overlong_prompt_refused(dense_setup):
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="exceeds this engine's limit"):
        eng.submit(Request(0, list(range(40)), max_new_tokens=2))


# --------------------------------------------------------------------------- #
# Gather kernel
# --------------------------------------------------------------------------- #
def test_paged_gather_matches_ref():
    from repro.kernels.paged import paged_gather, paged_gather_ref
    rng = np.random.default_rng(0)
    L, NB, block, D = 3, 7, 8, 10
    x = jnp.asarray(rng.normal(size=(L, NB * block, D)), jnp.float32)
    table = jnp.asarray(rng.integers(0, NB, size=(11,)), jnp.int32)
    got = paged_gather(x, table, block, interpret=True)
    want = paged_gather_ref(x, table, block)
    assert got.shape == want.shape == (L, 11 * block, D)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
