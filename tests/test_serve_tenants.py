"""Multi-tenant adapter serving (repro.serve.tenants): the cache, compaction,
and cross-adapter batching invariants.

The load-bearing claims, each bitwise where the design promises bitwise:
a cached delta IS the fresh replay (same apply_rank1 write path, xla AND
pallas-interpret); a compacted ledger materializes the same params as a full
replay; the byte-budgeted LRU evicts; a mixed-adapter batched decode emits
token-for-token what per-adapter sequential decode emits; and identity
mismatches refuse loudly (LedgerHashMismatchError + engine guardrails)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.trajectory import TrajectoryLedger, replay
from repro.models import bundle
from repro.models.config import ModelConfig
from repro.models.peft import merge_lora
from repro.serve.engine import Request, ServeEngine
from repro.serve.tenants import (AdapterDelta, AdapterStore, DeltaCache,
                                 LedgerHashMismatchError, compact,
                                 composition_for_ledger, lora_runtime,
                                 make_lora_tenants, materialize, serve_load,
                                 synthetic_requests, tenant_name)
from repro.serve.tenants.synth import lora_params0

BACKENDS = ["xla", "pallas-interpret"]
if os.environ.get("REPRO_BACKEND"):
    BACKENDS = [os.environ["REPRO_BACKEND"].replace("pallas", "pallas-interpret")
                if os.environ["REPRO_BACKEND"] == "pallas"
                else os.environ["REPRO_BACKEND"]]


def tiny_cfg():
    return ModelConfig(name="tenants-lm", family="dense", n_layers=2,
                       d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                       vocab_size=128, max_seq=64, dtype="float32")


@pytest.fixture(scope="module")
def base_setup():
    cfg = tiny_cfg()
    params = bundle(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def assert_trees_bitwise(a, b, msg=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes(), msg


# --------------------------------------------------------------------------- #
# content_hash (the cache-key primitive)
# --------------------------------------------------------------------------- #
def test_content_hash_roundtrip_and_sensitivity():
    led = TrajectoryLedger(base_seed=7, grad_dtype="float16")
    for s in range(5):
        led.append(s, 0.25 * (s + 1), 1e-4)
    # survives serialization (records hash post-quantization values)
    led2 = TrajectoryLedger.from_bytes(led.to_bytes())
    assert led2.content_hash() == led.content_hash()
    # prefix hashing matches a truncated ledger
    led3 = led.slice(0, 3)
    assert led.content_hash(upto=3) == led3.content_hash()
    # any record or header coordinate changes the digest
    led4 = TrajectoryLedger.from_bytes(led.to_bytes())
    led4.grads[2] = float(np.float16(9.0))
    assert led4.content_hash() != led.content_hash()
    led5 = TrajectoryLedger.from_bytes(led.to_bytes())
    led5.base_seed = 8
    assert led5.content_hash() != led.content_hash()
    with pytest.raises(ValueError):
        led.content_hash(upto=99)


def test_store_refuses_corrupted_blob():
    led = TrajectoryLedger(base_seed=1)
    led.append(0, 0.5, 1e-4)
    other = TrajectoryLedger(base_seed=2)
    other.append(0, 0.25, 1e-4)
    store = AdapterStore()
    key = store.put("t", led)
    assert store.key("t") == key
    store._blobs[key[0]] = other.to_bytes()     # simulate a mis-filed blob
    with pytest.raises(LedgerHashMismatchError):
        store.ledger("t")
    with pytest.raises(KeyError):
        store.key("unknown")


# --------------------------------------------------------------------------- #
# cached delta ≡ fresh replay, per backend
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_cached_delta_bitwise_equals_fresh_replay(base_setup, backend):
    cfg, base = base_setup
    store = make_lora_tenants(cfg, base, 2, steps=3, batch=4, backend=backend)
    rt = lora_runtime(cfg, base, store, cache_bytes=10_000_000)
    delta = rt.delta(tenant_name(0))
    folds = rt.records_replayed
    assert folds == 3                     # the cold materialization replayed
    assert rt.delta(tenant_name(0)) is delta   # hit: same buffers
    assert rt.records_replayed == folds        # ...and zero further folds

    led = store.ledger(tenant_name(0))
    assert led.backend == composition_for_ledger(led).backend_name
    tuned = replay(lora_params0(cfg, base, led), led,
                   composition_for_ledger(led))
    fresh = merge_lora(tuned["base"], tuned["lora"])
    assert_trees_bitwise(delta.apply(base), fresh,
                         f"cached delta != fresh replay under {backend}")


@pytest.mark.parametrize("backend", BACKENDS)
def test_compaction_bitwise_equals_full_replay(base_setup, backend):
    cfg, base = base_setup
    store = make_lora_tenants(cfg, base, 1, steps=6, batch=4, backend=backend,
                              seed0=300)
    led = store.ledger(tenant_name(0))
    opt = composition_for_ledger(led)
    p0 = lora_params0(cfg, base, led)
    full = replay(p0, led, opt)
    for keep_tail in (0, 2, 6, 99):
        comp = compact(p0, led, opt, keep_tail=keep_tail)
        assert comp.upto == max(0, 6 - keep_tail)
        assert len(comp.tail) == 6 - comp.upto
        assert_trees_bitwise(materialize(p0, comp, opt, ledger=led), full,
                             f"compacted (tail={keep_tail}) != full replay")


def test_compaction_refuses_mismatched_ledger(base_setup):
    cfg, base = base_setup
    store = make_lora_tenants(cfg, base, 2, steps=4, batch=4, seed0=400)
    led_a = store.ledger(tenant_name(0))
    led_b = store.ledger(tenant_name(1))
    opt = composition_for_ledger(led_a)
    comp = compact(lora_params0(cfg, base, led_a), led_a, opt, keep_tail=1)
    with pytest.raises(LedgerHashMismatchError):
        materialize(lora_params0(cfg, base, led_b), comp, opt, ledger=led_b)
    store2 = AdapterStore()
    store2.put("b", led_b)
    with pytest.raises(LedgerHashMismatchError):
        store2.put_compacted("b", comp)


def test_runtime_uses_compacted_tail(base_setup):
    cfg, base = base_setup
    store = make_lora_tenants(cfg, base, 1, steps=8, batch=4, seed0=500)
    rt = lora_runtime(cfg, base, store, cache_bytes=10_000_000)
    t = tenant_name(0)
    full_delta = rt.delta(t)
    assert rt.records_replayed == 8
    comp = rt.compact_tenant(t, keep_tail=2)
    assert comp.upto == 6 and len(comp.tail) == 2
    rt.cache._entries.clear()             # force a cold re-materialization
    rt.cache.bytes = 0
    rt2_folds = rt.records_replayed
    delta2 = rt.delta(t)
    assert rt.records_replayed == rt2_folds + 2   # O(tail), not O(steps)
    assert_trees_bitwise(delta2.apply(base), full_delta.apply(base),
                         "compacted materialization != full")


# --------------------------------------------------------------------------- #
# DeltaCache: byte-budgeted LRU
# --------------------------------------------------------------------------- #
def _delta_of_bytes(n_floats, tag):
    v = jnp.full((n_floats,), float(tag), jnp.float32)
    return AdapterDelta((0,), (v,), 1, 1)


def test_delta_cache_lru_eviction_under_byte_budget():
    cache = DeltaCache(budget_bytes=1024)          # holds two 100-float deltas
    d = {k: _delta_of_bytes(100, i) for i, k in enumerate("abc")}
    cache.put("a", d["a"])
    cache.put("b", d["b"])
    assert cache.get("a") is d["a"]                # refresh a: b is now LRU
    cache.put("c", d["c"])                         # 1200 B > budget -> evict b
    assert cache.get("b") is None
    assert cache.get("a") is d["a"] and cache.get("c") is d["c"]
    assert cache.evictions == 1 and cache.bytes == 800
    # an entry bigger than the whole budget is refused, not destructive
    assert not cache.put("big", _delta_of_bytes(1000, 9))
    assert cache.oversize == 1 and len(cache) == 2
    stats = cache.stats
    assert stats["hits"] == 3 and stats["misses"] == 1
    with pytest.raises(ValueError):
        DeltaCache(0)


def test_adapter_delta_diff_and_apply_are_exact(base_setup):
    _, base = base_setup
    leaves, treedef = jax.tree_util.tree_flatten(base)
    changed = list(leaves)
    changed[1] = changed[1] + jnp.float32(0.125)
    tuned = jax.tree_util.tree_unflatten(treedef, changed)
    delta = AdapterDelta.diff(base, tuned)
    assert delta.indices == (1,)
    assert not delta.full_tree
    assert_trees_bitwise(delta.apply(base), tuned)
    # applying against a differently-shaped tree refuses
    small = jax.tree_util.tree_unflatten(
        treedef, [l[..., :1] for l in leaves])
    with pytest.raises(ValueError):
        delta.apply(small)


# --------------------------------------------------------------------------- #
# Engine: mixed-adapter batching + guardrails + timestamps
# --------------------------------------------------------------------------- #
def _sequential_reference(cfg, base, rt, tagged, n_new):
    """Per-adapter sequential decode: one single-slot engine per request."""
    outs = []
    for tenant, req in tagged:
        e1 = ServeEngine(cfg, base, slots=1, max_len=48)
        if tenant is not None:
            e1.register_adapter(tenant, rt.delta(tenant))
        r1 = Request(req.rid, list(req.prompt_ids), max_new_tokens=n_new,
                     adapter=tenant)
        e1.submit(r1)
        e1.run()
        outs.append(r1.out_ids)
    return outs


def test_mixed_adapter_batch_matches_sequential(base_setup):
    cfg, base = base_setup
    store = make_lora_tenants(cfg, base, 3, steps=3, batch=4, seed0=600)
    rt = lora_runtime(cfg, base, store, cache_bytes=10_000_000)
    engine = ServeEngine(cfg, base, slots=3, max_len=48)
    tagged = synthetic_requests(7, cfg.vocab_size, store.tenants(), seed=2,
                                max_new_tokens=5)
    tagged[3] = (None, tagged[3][1])      # one base-model request in the mix
    serve_load(engine, rt, tagged)
    want = _sequential_reference(cfg, base, rt, tagged, 5)
    for (tenant, req), ref in zip(tagged, want):
        assert req.out_ids == ref, (tenant, req.rid, req.out_ids, ref)


def test_full_tree_delta_takes_grouped_path(base_setup):
    cfg, base = base_setup
    noisy = jax.tree_util.tree_map(
        lambda a: a + jnp.asarray(0.01, a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, base)
    full_delta = AdapterDelta.diff(base, noisy)
    assert full_delta.full_tree
    engine = ServeEngine(cfg, base, slots=2, max_len=48)
    engine.register_adapter("full", full_delta)
    reqs = [Request(0, [3, 5, 7], max_new_tokens=4, adapter="full"),
            Request(1, [11, 13], max_new_tokens=4)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, (params, name) in zip(reqs, [(noisy, "full"), (base, None)]):
        e1 = ServeEngine(cfg, base, slots=1, max_len=48)
        if name:
            e1.register_adapter(name, full_delta)
        r1 = Request(r.rid, list(r.prompt_ids), max_new_tokens=4, adapter=name)
        e1.submit(r1)
        e1.run()
        assert r1.out_ids == r.out_ids


def test_engine_refuses_overlong_prompt_and_unknown_adapter(base_setup):
    cfg, base = base_setup
    engine = ServeEngine(cfg, base, slots=1, max_len=16)
    with pytest.raises(ValueError, match="exceeds this engine's limit"):
        engine.submit(Request(0, list(range(1, 20)), max_new_tokens=2))
    with pytest.raises(KeyError, match="not registered"):
        engine.submit(Request(1, [1, 2], adapter="ghost"))
    assert not engine.queue               # nothing was half-admitted


def test_request_timestamp_trail(base_setup):
    cfg, base = base_setup
    engine = ServeEngine(cfg, base, slots=1, max_len=32)
    r = Request(0, [4, 5, 6], max_new_tokens=3)
    engine.submit(r)
    engine.run()
    assert r.done
    ts = r.times
    assert set(ts) >= {"queued", "prefill", "decode", "done"}
    assert ts["queued"] <= ts["prefill"] <= ts["decode"] <= ts["done"]


# --------------------------------------------------------------------------- #
# The acceptance scenario: 64 LoRA tenants through one engine
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_64_tenant_acceptance(base_setup):
    cfg, base = base_setup
    store = make_lora_tenants(cfg, base, 64, steps=2, batch=4, seed0=700)
    rt = lora_runtime(cfg, base, store, cache_bytes=200_000_000)
    engine = ServeEngine(cfg, base, slots=4, max_len=48)
    tagged = synthetic_requests(24, cfg.vocab_size, store.tenants(), seed=3,
                                max_new_tokens=4)
    serve_load(engine, rt, tagged)
    want = _sequential_reference(cfg, base, rt, tagged, 4)
    for (tenant, req), ref in zip(tagged, want):
        assert req.out_ids == ref, (tenant, req.rid)
    assert rt.stats["hit_rate"] > 0       # repeated tenants hit the cache
