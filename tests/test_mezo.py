"""MeZO optimizer behaviour: convergence, in-place chain equivalence,
n-SPSA, schedules, estimators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MeZO, MeZOConfig
from repro.core.mezo import apply_projected_update
from repro.core.perturb import perturb, step_key
from repro.tree_utils import tree_max_abs_diff


def target_tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (12,)),
            "b": jax.random.normal(k2, (3, 5))}


def make_quad(t):
    def loss(p, batch):
        return 0.5 * sum(jnp.sum((x - y) ** 2) for x, y in
                         zip(jax.tree_util.tree_leaves(p),
                             jax.tree_util.tree_leaves(t)))
    return loss


def test_mezo_converges_quadratic():
    t = target_tree(jax.random.PRNGKey(0))
    loss_fn = make_quad(t)
    params = jax.tree_util.tree_map(jnp.zeros_like, t)
    opt = MeZO(MeZOConfig(lr=5e-3, eps=1e-3))
    state = opt.init(0)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    l0 = float(loss_fn(params, None))
    for _ in range(2500):
        params, state, m = step(params, state, None)
    lT = float(loss_fn(params, None))
    assert lT < 1e-3 * l0, (l0, lT)


def test_sequential_equals_center_perturb():
    """sequential in-place chain (paper) == center-perturb variant up to the
    fp error of the extra additions."""
    t = target_tree(jax.random.PRNGKey(1))
    loss_fn = make_quad(t)
    p0 = jax.tree_util.tree_map(jnp.zeros_like, t)
    outs = []
    for seq in (True, False):
        opt = MeZO(MeZOConfig(lr=1e-3, eps=1e-3, sequential_perturb=seq))
        state = opt.init(42)
        step = jax.jit(opt.step_fn(loss_fn))
        p = p0
        for _ in range(20):
            p, state, _ = step(p, state, None)
        outs.append(p)
    assert tree_max_abs_diff(outs[0], outs[1]) < 1e-4


def test_update_matches_manual_rank1():
    """θ' − θ == −η·g·z with z regenerated from the step seed."""
    t = target_tree(jax.random.PRNGKey(2))
    loss_fn = make_quad(t)
    p0 = jax.tree_util.tree_map(jnp.ones_like, t)
    cfg = MeZOConfig(lr=1e-3, eps=1e-3)
    opt = MeZO(cfg)
    state = opt.init(3)
    p1, state1, m = jax.jit(opt.step_fn(loss_fn))(p0, state, None)
    skey = step_key(opt.init(3).base_key, jnp.int32(0))
    manual = apply_projected_update(p0, skey, m["projected_grad"], cfg.lr)
    assert tree_max_abs_diff(p1, manual) < 1e-5


def test_nspsa_reduces_direction_variance():
    """n-SPSA direction correlates better with the true gradient."""
    t = target_tree(jax.random.PRNGKey(3))
    loss_fn = make_quad(t)
    p0 = jax.tree_util.tree_map(jnp.zeros_like, t)
    true_g = jax.grad(lambda p: loss_fn(p, None))(p0)

    def mean_cos(n, trials=40):
        opt = MeZO(MeZOConfig(lr=1e-3, eps=1e-3, n=n))
        cs = []
        for s in range(trials):
            state = opt.init(s)
            p1, _, _ = jax.jit(opt.step_fn(loss_fn))(p0, state, None)
            delta = jax.tree_util.tree_map(lambda a, b: a - b, p0, p1)
            num = sum(jnp.sum(d * g) for d, g in
                      zip(jax.tree_util.tree_leaves(delta),
                          jax.tree_util.tree_leaves(true_g)))
            den = jnp.sqrt(sum(jnp.sum(d * d) for d in jax.tree_util.tree_leaves(delta))) * \
                jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(true_g)))
            cs.append(float(num / den))
        return np.mean(cs)

    assert mean_cos(8) > mean_cos(1) + 0.1


def test_one_point_estimator_runs_and_descends():
    t = target_tree(jax.random.PRNGKey(4))
    loss_fn = make_quad(t)
    params = jax.tree_util.tree_map(jnp.zeros_like, t)
    opt = MeZO(MeZOConfig(lr=2e-4, eps=1e-2, estimator="one_point"))
    state = opt.init(0)
    step = jax.jit(opt.step_fn(loss_fn))
    l0 = float(loss_fn(params, None))
    for _ in range(3000):
        params, state, m = step(params, state, None)
    assert float(loss_fn(params, None)) < 0.7 * l0


def test_lr_schedules():
    cfg = MeZOConfig(lr=1.0, lr_schedule="linear", total_steps=100)
    assert float(cfg.lr_at(jnp.int32(0))) == pytest.approx(1.0)
    assert float(cfg.lr_at(jnp.int32(50))) == pytest.approx(0.5)
    cfg = MeZOConfig(lr=1.0, lr_schedule="constant", warmup_steps=10)
    assert float(cfg.lr_at(jnp.int32(0))) == pytest.approx(0.1)


def test_weight_decay_applied():
    loss_fn = lambda p, b: jnp.float32(0.0) * jnp.sum(p["w"])  # zero gradient
    p0 = {"w": jnp.ones((8,))}
    opt = MeZO(MeZOConfig(lr=0.1, eps=1e-3, weight_decay=0.5))
    state = opt.init(0)
    p1, _, _ = jax.jit(opt.step_fn(loss_fn))(p0, state, None)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               0.95 * np.ones(8), rtol=1e-3)


def test_projected_grad_clipping():
    loss_fn = lambda p, b: 1e6 * jnp.sum(p["w"])
    p0 = {"w": jnp.zeros((8,))}
    opt = MeZO(MeZOConfig(lr=1e-3, eps=1e-3, clip_projected_grad=1.0))
    state = opt.init(0)
    _, _, m = jax.jit(opt.step_fn(loss_fn))(p0, state, None)
    assert abs(float(m["projected_grad"])) <= 1.0


def test_variance_modified_variant_descends():
    """App. B.3 optimizer (D = parameter norms) optimizes the quadratic."""
    from repro.core.mezo_variants import MeZOVariant, MeZOVariantConfig
    t = target_tree(jax.random.PRNGKey(9))
    loss_fn = make_quad(t)
    params = jax.tree_util.tree_map(jnp.ones_like, t)
    opt = MeZOVariant(MeZOVariantConfig(lr=5e-3, eps=1e-3,
                                        d_source="param_norm"))
    state = opt.init(params)
    step = jax.jit(opt.step_fn(loss_fn))
    l0 = float(loss_fn(params, None))
    for _ in range(1500):
        params, state, m = step(params, state, None)
    assert float(loss_fn(params, None)) < 0.1 * l0


def test_variance_modified_unbiased_same_expectation():
    """Definition 6 keeps E[update direction] ∝ ∇L: one step from a clean
    quadratic moves downhill on average."""
    from repro.core.mezo_variants import MeZOVariant, MeZOVariantConfig
    t = {"w": jnp.ones((16,))}
    loss_fn = make_quad(t)
    p0 = {"w": jnp.zeros((16,))}
    opt = MeZOVariant(MeZOVariantConfig(lr=1e-2, eps=1e-3,
                                        d_source="param_norm"))
    deltas = jnp.zeros((16,))
    for s in range(300):
        state = opt.init(p0)
        state = state._replace(base_key=jax.random.PRNGKey(s))
        p1, _, _ = jax.jit(opt.step_fn(loss_fn))(p0, state, None)
        deltas = deltas + (p1["w"] - p0["w"]) / 300
    true_dir = -jax.grad(lambda p: loss_fn(p, None))(p0)["w"]
    cos = jnp.dot(deltas, true_dir) / (jnp.linalg.norm(deltas)
                                       * jnp.linalg.norm(true_dir))
    assert float(cos) > 0.8, float(cos)
