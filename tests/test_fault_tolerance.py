"""Fault tolerance: crash/restart bitwise continuation, ledger-tail recovery,
checkpoint rotation/atomicity, and step-indexed data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import MeZO, MeZOConfig, TrajectoryLedger
from repro.data.pipeline import DataSpec, Pipeline
from repro.models import all_archs, bundle
from repro.train.loop import FailureInjector, train
from repro.tree_utils import tree_max_abs_diff


@pytest.fixture()
def setup(tmp_path):
    cfg = all_archs()["qwen2-0.5b"].smoke_cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()
    pipe = Pipeline(DataSpec("lm", batch=4, seq=16, vocab=cfg.vocab_size, seed=9))
    opt = MeZO(MeZOConfig(lr=1e-4, eps=1e-3))
    return cfg, b, params, loss_fn, pipe, opt, str(tmp_path)


def test_crash_resume_bitwise(setup):
    cfg, b, params, loss_fn, pipe, opt, tmp = setup
    T = 12

    # uninterrupted reference run (no checkpointing side effects)
    ref = train(loss_fn, params, opt, pipe, total_steps=T, donate=False)

    # crashing run: full ckpt every 5 steps + per-step ledger
    ck = CheckpointManager(os.path.join(tmp, "run"), interval=5)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    with pytest.raises(RuntimeError, match="injected failure"):
        train(loss_fn, params, opt, pipe, total_steps=T, ckpt=ck, ledger=led,
              injector=FailureInjector(fail_at_step=8), donate=False)

    # replacement worker: restores ckpt@5 + replays ledger steps 5..7
    led2 = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    res = train(loss_fn, params, opt, pipe, total_steps=T, ckpt=ck,
                ledger=led2, donate=False)
    assert res.resumed_from == 8          # ledger head (crash point)
    assert tree_max_abs_diff(res.params, ref.params) < 1e-6


def test_ledger_recovery_no_forward_passes(setup):
    """Recovery applies scalar updates only — verify by giving the recovery a
    loss_fn that would explode if called."""
    cfg, b, params, loss_fn, pipe, opt, tmp = setup
    ck = CheckpointManager(os.path.join(tmp, "r2"), interval=100)  # no mid ckpts
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    r = train(loss_fn, params, opt, pipe, total_steps=6, ckpt=ck, ledger=led,
              donate=False)
    led_loaded = ck.load_ledger()
    assert led_loaded is not None and len(led_loaded) == 6
    recovered, head = ck.recover_via_ledger(params, 0, opt.config)
    assert head == 6
    assert tree_max_abs_diff(recovered, r.params) < 1e-6


def test_checkpoint_rotation(tmp_path):
    ck = CheckpointManager(str(tmp_path), interval=1, keep=2)
    p = {"w": jnp.ones((4,))}
    for s in range(1, 6):
        ck.maybe_save(s, {"w": p["w"] * s})
    assert ck.steps() == [4, 5]


def test_checkpoint_roundtrip_dtypes(tmp_path):
    from repro.checkpoint.io import load_tree, save_tree
    tree = {"a": jnp.ones((3, 3), jnp.bfloat16),
            "b": jnp.arange(5, dtype=jnp.int32),
            "c": {"d": jnp.float32(2.5)}}
    path = str(tmp_path / "t.mz")
    save_tree(path, tree, {"step": 7})
    loaded, meta = load_tree(path, tree)
    assert meta["step"] == 7
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_data_pipeline_stateless_restart():
    pipe = Pipeline(DataSpec("lm", batch=4, seq=8, vocab=100, seed=3))
    a = pipe.batch(17)
    b = pipe.batch(17)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = pipe.batch(18)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_elastic_mesh_derivation():
    from repro.launch.mesh import make_elastic_mesh
    m = make_elastic_mesh(n_devices=1)
    assert m.devices.size == 1
