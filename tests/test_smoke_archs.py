"""REQUIRED per-architecture smoke tests: instantiate the REDUCED config of
each assigned arch's family, run one forward + one MeZO train step on CPU,
assert output shapes + no NaNs.  Also checks serving consistency: an
incremental decode step must match the teacher-forcing forward on the same
prefix (cache/state correctness), per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS
from repro.core import MeZO, MeZOConfig
from repro.models import all_archs, bundle, cells_for

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch_id", ALL)
def test_forward_and_mezo_step(arch_id, key):
    arch = all_archs()[arch_id]
    cfg = arch.smoke_cfg
    b = bundle(cfg)
    params = b.init(key)
    batch = b.make_batch(key, batch=2, seq=16)
    loss_fn = b.loss_fn()
    loss = loss_fn(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch_id

    opt = MeZO(MeZOConfig(lr=1e-4, eps=1e-3))
    state = opt.init(0)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    new_params, state, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch_id
    assert bool(jnp.isfinite(metrics["projected_grad"])), arch_id
    for a, b_ in zip(jax.tree_util.tree_leaves(new_params),
                     jax.tree_util.tree_leaves(b.init(key))):
        assert a.shape == b_.shape
        if jnp.issubdtype(a.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(a.astype(jnp.float32)))), arch_id


@pytest.mark.parametrize("arch_id", ["qwen2-0.5b", "yi-6b", "mixtral-8x7b",
                                     "granite-moe-3b-a800m", "hymba-1.5b",
                                     "rwkv6-3b", "phi-3-vision-4.2b",
                                     "nemotron-4-340b"])
def test_decode_matches_teacher_forcing(arch_id, key):
    """prefill S tokens -> decode token S must equal the (S+1)-token
    teacher-forcing forward at the last position."""
    arch = all_archs()[arch_id]
    cfg = arch.smoke_cfg
    if cfg.n_experts:
        # capacity-based MoE drops are CONTEXT dependent (GShard semantics):
        # make capacity non-binding so decode == teacher forcing exactly
        cfg = cfg.replace(capacity_factor=8.0)
    b = bundle(cfg)
    params = b.init(key)
    S = 12
    toks = jax.random.randint(key, (2, S + 1), 0, cfg.vocab_size)

    # full forward (training path)
    from repro.models import rwkv6, transformer
    if cfg.family == "ssm":
        full_logits, _ = rwkv6.forward(cfg, params, tokens=toks)
    else:
        full_logits = transformer.forward(cfg, params, tokens=toks).logits

    # prefill S, then decode token S at position S
    pre = {"tokens": toks[:, :S]}
    logits_p, st = jax.jit(b.prefill_fn())(params, pre)
    dbatch = {"token": toks[:, S:S + 1], "cache_pos": jnp.int32(S)}
    if cfg.family == "ssm":
        dbatch["state"] = st
    elif cfg.family == "hybrid":
        dbatch["cache"], dbatch["state"] = st
    else:
        dbatch["cache"] = st
    dec_logits, _ = jax.jit(b.decode_fn())(params, dbatch)

    a = np.asarray(full_logits[:, S, :cfg.vocab_size], np.float32)
    c = np.asarray(dec_logits[:, 0, :cfg.vocab_size], np.float32)
    np.testing.assert_allclose(a, c, rtol=2e-3, atol=2e-3)
    # and the prefill's own last logit matches position S-1
    a2 = np.asarray(full_logits[:, S - 1, :cfg.vocab_size], np.float32)
    c2 = np.asarray(logits_p[:, 0, :cfg.vocab_size], np.float32)
    np.testing.assert_allclose(a2, c2, rtol=2e-3, atol=2e-3)


def test_whisper_decode_matches_teacher_forcing(key):
    arch = all_archs()["whisper-large-v3"]
    cfg = arch.smoke_cfg
    b = bundle(cfg)
    params = b.init(key)
    S = 10
    frames = jax.random.normal(key, (2, 16, cfg.d_model), cfg.param_dtype) * 0.02
    toks = jax.random.randint(key, (2, S + 1), 0, cfg.vocab_size)

    from repro.models import encdec
    full = encdec.forward_train(cfg, params, frames, toks)

    pre = {"frames": frames, "tokens": toks[:, :1]}
    _, (cache, cross_kv) = jax.jit(b.prefill_fn())(params, pre)
    # feed tokens 1..S incrementally
    logits = None
    for t in range(1, S + 1):
        dbatch = {"token": toks[:, t:t + 1], "cache_pos": jnp.int32(t),
                  "cache": cache, "cross_kv": cross_kv}
        logits, cache = jax.jit(b.decode_fn())(params, dbatch)
    a = np.asarray(full[:, S, :cfg.vocab_size], np.float32)
    c = np.asarray(logits[:, 0, :cfg.vocab_size], np.float32)
    np.testing.assert_allclose(a, c, rtol=2e-3, atol=2e-3)


def test_swa_ring_cache_long_decode(key):
    """Hymba-family SWA ring buffer: decoding far past the window must agree
    with the full forward (whose mask also limits to the window)."""
    cfg = all_archs()["hymba-1.5b"].smoke_cfg   # window 16
    b = bundle(cfg)
    params = b.init(key)
    T = 40   # >> window
    toks = jax.random.randint(key, (1, T + 1), 0, cfg.vocab_size)
    from repro.models import transformer
    full_logits = transformer.forward(cfg, params, tokens=toks).logits

    pre = {"tokens": toks[:, :8]}
    _, (cache, state) = jax.jit(b.prefill_fn())(params, pre)
    dec = jax.jit(b.decode_fn())
    logits = None
    for t in range(8, T + 1):
        dbatch = {"token": toks[:, t:t + 1], "cache_pos": jnp.int32(t),
                  "cache": cache, "state": state}
        logits, (cache, state) = dec(params, dbatch)
    a = np.asarray(full_logits[:, T, :cfg.vocab_size], np.float32)
    c = np.asarray(logits[:, 0, :cfg.vocab_size], np.float32)
    np.testing.assert_allclose(a, c, rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("arch_id", ALL)
def test_full_config_param_count_sane(arch_id):
    """The production config's analytic parameter count is in the right
    ballpark for its name (catches config transcription errors)."""
    expected = {
        "qwen2-0.5b": (0.3e9, 0.8e9), "qwen2-7b": (6e9, 9e9),
        "yi-6b": (5e9, 7.5e9), "nemotron-4-340b": (300e9, 380e9),
        "phi-3-vision-4.2b": (3.3e9, 4.6e9), "mixtral-8x7b": (42e9, 50e9),
        "granite-moe-3b-a800m": (2e9, 4e9), "hymba-1.5b": (1.0e9, 2.2e9),
        "rwkv6-3b": (2.5e9, 4e9), "whisper-large-v3": (1.2e9, 2.2e9),
        "opt-13b": (11e9, 15e9), "opt-30b": (27e9, 34e9),
        "opt-66b": (60e9, 72e9), "roberta-large": (0.3e9, 0.5e9),
    }
    cfg = all_archs()[arch_id].cfg
    lo, hi = expected[arch_id]
    n = cfg.n_params()
    assert lo <= n <= hi, (arch_id, n)


def test_cells_for_skips():
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    names = {a: [c.name for c in cells_for(all_archs()[a].cfg)]
             for a in ASSIGNED_ARCHS}
    assert "long_500k" in names["rwkv6-3b"]
    assert "long_500k" in names["hymba-1.5b"]
    for a in ASSIGNED_ARCHS:
        if a not in ("rwkv6-3b", "hymba-1.5b"):
            assert "long_500k" not in names[a], a
    total = sum(len(v) for v in names.values())
    assert total == 32   # 10*3 + 2 long_500k
