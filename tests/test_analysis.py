"""Roofline machinery unit tests: collective HLO parsing, model-FLOPs
accounting, report generation."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import flops as flops_lib
from repro.analysis.roofline import (Roofline, _shape_bytes,
                                     collective_stats)
from repro.models import all_archs
from repro.models.config import DECODE_32K, PREFILL_32K, TRAIN_4K

HLO = """
  %all-reduce = f32[16,4096,512]{2,1,0} all-reduce(%add), channel_id=1, replica_groups=[2,4]<=[8]
  %ag = bf16[128,256]{1,0} all-gather(%p0), dimensions={0}
  %rs.1 = f32[64]{0} reduce-scatter(%x), dimensions={0}
  %a2a = f32[8,8]{1,0} all-to-all(%y), dimensions={0}
  %cp = u32[4]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %ar-start = f32[32]{0} all-reduce-start(%w), channel_id=2
  %ar-done = f32[32]{0} all-reduce-done(%ar-start)
  %not-a-collective = f32[9]{0} add(%a, %b)
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,4096,512]") == 16 * 4096 * 512 * 4
    assert _shape_bytes("bf16[128,256]") == 128 * 256 * 2
    assert _shape_bytes("(f32[8], bf16[4])") == 8 * 4 + 4 * 2


def test_collective_stats_parsing():
    st = collective_stats(HLO)
    assert st["all-reduce"]["count"] == 2          # plain + -start (not -done)
    assert st["all-reduce"]["bytes"] == 2 * (16 * 4096 * 512 * 4) + 2 * 32 * 4
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 128 * 256 * 2
    assert st["reduce-scatter"]["count"] == 1
    assert st["all-to-all"]["count"] == 1
    assert st["collective-permute"]["count"] == 1
    assert st["total_count"] == 6


def test_roofline_terms_and_bottleneck():
    r = Roofline(arch="x", cell="train_4k", mesh="single", chips=256,
                 flops_per_chip=197e12,          # exactly 1s of compute
                 hbm_bytes_per_chip=819e9 * 2,   # 2s of memory
                 link_bytes_per_chip=50e9 * 0.5, # 0.5s of collectives
                 model_flops=int(197e12 * 256), model_flops_6nd=0).finalize()
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.bottleneck == "memory"
    assert r.step_s == pytest.approx(2.0)
    assert r.roofline_fraction == pytest.approx(0.5)
    assert r.useful_ratio == pytest.approx(1.0)


@pytest.mark.parametrize("arch_id", ["qwen2-7b", "mixtral-8x7b", "rwkv6-3b",
                                     "whisper-large-v3", "hymba-1.5b"])
def test_model_flops_scaling(arch_id):
    """Train cell counts ~2 forwards; MoE uses active params; decode is
    per-token."""
    cfg = all_archs()[arch_id].cfg
    tr = flops_lib.model_flops(cfg, TRAIN_4K, "mezo")
    pf = flops_lib.model_flops(cfg, PREFILL_32K, "mezo")
    de = flops_lib.model_flops(cfg, DECODE_32K, "mezo")
    assert tr["model_flops"] > 0 and de["model_flops"] > 0
    # two forwards vs one at equal token counts
    tr1 = flops_lib.model_flops(cfg, TRAIN_4K, "ft")
    assert tr1["model_flops"] > tr["model_flops"]   # fwd+bwd > 2 fwd? (3 vs 2)
    # decode flops are ~B/(B*S) of prefill flops (same params term)
    assert de["model_flops"] < pf["model_flops"] / 100
    if cfg.n_experts:
        assert tr["backbone_params_active"] < cfg.n_params()


def test_moe_active_params():
    cfg = all_archs()["mixtral-8x7b"].cfg
    assert cfg.n_active_params() < 0.35 * cfg.n_params()
