"""Serving engine: batched continuous decode must match direct greedy
decoding of the same model, slots recycle, and families dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import all_archs, bundle
from repro.models import transformer, rwkv6
from repro.serve.engine import Request, ServeEngine


def greedy_reference(cfg, params, prompt_ids, n_new):
    """Direct full-recompute greedy decoding (O(S²) but trivially correct)."""
    ids = list(prompt_ids)
    for _ in range(n_new):
        toks = jnp.asarray([ids], jnp.int32)
        if cfg.family == "ssm":
            logits, _ = rwkv6.forward(cfg, params, tokens=toks)
        else:
            logits = transformer.forward(cfg, params, tokens=toks).logits
        ids.append(int(jnp.argmax(logits[0, -1, :cfg.vocab_size])))
    return ids[len(prompt_ids):]


@pytest.mark.parametrize("arch_id", ["qwen2-0.5b", "rwkv6-3b", "hymba-1.5b"])
def test_engine_matches_reference(arch_id):
    cfg = all_archs()[arch_id].smoke_cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=2, max_len=64)
    prompts = [[3, 5, 7, 9], [11, 13, 17]]
    n_new = 5
    reqs = [Request(i, p, max_new_tokens=n_new) for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r, p in zip(reqs, prompts):
        want = greedy_reference(cfg, params, p, n_new)
        assert r.out_ids == want, (arch_id, r.rid, r.out_ids, want)


def test_slots_recycle_more_requests_than_slots():
    cfg = all_archs()["qwen2-0.5b"].smoke_cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=2, max_len=64)
    reqs = [Request(i, [2 + i, 3 + i], max_new_tokens=3) for i in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out_ids) == 3 for r in reqs)


@pytest.mark.parametrize("plen", [64, 65])
def test_prompt_at_old_prefill_width_boundary(plen):
    """Regression for the hard-coded 64-wide prefill pad: prompts of exactly
    64 and 65 tokens must both decode correctly (65 crosses into the next
    derived bucket instead of silently colliding with a fixed width)."""
    cfg = all_archs()["qwen2-0.5b"].smoke_cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=1, max_len=128)
    prompt = [(3 * i) % 200 + 2 for i in range(plen)]
    r = Request(0, prompt, max_new_tokens=4)
    engine.submit(r)
    engine.run()
    assert r.out_ids == greedy_reference(cfg, params, prompt, 4)


def test_temperature_sampling_runs():
    cfg = all_archs()["qwen2-0.5b"].smoke_cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, slots=1, max_len=32, seed=1)
    r = Request(0, [4, 5], max_new_tokens=4, temperature=1.0)
    engine.submit(r)
    engine.run()
    assert len(r.out_ids) == 4
    assert all(0 <= t < cfg.vocab_size for t in r.out_ids)
