"""MoE dispatch: capacity semantics, combine-weight invariants, and exactness
against a per-token reference router when capacity is unconstrained."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import all_archs
from repro.models.common import KeyGen
from repro.models.ffn import ffn
from repro.models.moe import moe_ffn, moe_params


@pytest.fixture(scope="module")
def setup():
    cfg = all_archs()["mixtral-8x7b"].smoke_cfg.replace(
        capacity_factor=8.0, moe_group_size=16)   # capacity ~never binds
    p = moe_params(cfg, KeyGen(jax.random.PRNGKey(0)), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    return cfg, p, x


def _reference_moe(cfg, p, x):
    """Per-token loop: softmax router, top-k renormalized, dense experts."""
    B, S, d = x.shape
    logits = np.asarray((x @ p["router"]).astype(jnp.float32))
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    out = np.zeros((B, S, d), np.float32)
    for b in range(B):
        for s in range(S):
            pr = np.asarray(probs[b, s])
            top = np.argsort(-pr)[:cfg.top_k]
            w = pr[top] / pr[top].sum()
            for wi, e in zip(w, top):
                xe = x[b, s][None, None]
                h = xe @ p["w1"][e]
                h = jax.nn.silu(h) * (xe @ p["w3"][e])
                out[b, s] += wi * np.asarray((h @ p["w2"][e])[0, 0])
    return out


def test_moe_matches_reference_when_capacity_unbound(setup):
    cfg, p, x = setup
    got, aux = moe_ffn(cfg, p, x)
    want = _reference_moe(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=2e-3)


def test_moe_aux_loss_near_one_for_uniform(setup):
    """Switch aux loss is ~1 when routing is near uniform (random init)."""
    cfg, p, x = setup
    _, aux = moe_ffn(cfg, p, x)
    assert 0.5 * cfg.top_k < float(aux) < 2.5 * cfg.top_k


def test_moe_capacity_drops_tokens():
    """With capacity << assignments most tokens are dropped -> output
    shrinks.  (Capacity has an 8-slot floor, so use a 64-token group: 128
    assignments vs 4 experts x 8 slots = 75% dropped.)"""
    cfg = all_archs()["mixtral-8x7b"].smoke_cfg.replace(moe_group_size=64)
    p = moe_params(cfg, KeyGen(jax.random.PRNGKey(0)), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    full, _ = moe_ffn(cfg.replace(capacity_factor=8.0), p, x)
    tiny, _ = moe_ffn(cfg.replace(capacity_factor=0.01), p, x)
    assert float(jnp.mean(jnp.abs(tiny))) < 0.75 * float(jnp.mean(jnp.abs(full)))


def test_granite_40_experts_top8_shapes():
    cfg = all_archs()["granite-moe-3b-a800m"].smoke_cfg
    p = moe_params(cfg, KeyGen(jax.random.PRNGKey(0)), jnp.float32)
    assert p["w1"].shape[0] == cfg.n_experts
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = moe_ffn(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
