"""MeZO-Adam / momentum: the recomputed-from-scalars optimizer state
(paper App. B.2) must match the materialized oracle within the window-
truncation error."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import MeZOAdam, MeZOAdamConfig
from repro.tree_utils import tree_max_abs_diff


def setup(materialized, window=64, momentum_only=False, steps=12, lr=1e-3):
    key = jax.random.PRNGKey(0)
    t = {"w": jax.random.normal(key, (16,))}
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["w"] - t["w"]) ** 2)
    cfg = MeZOAdamConfig(lr=lr, eps=1e-3, beta1=0.9, beta2=0.95,
                         materialized=materialized, window=window,
                         momentum_only=momentum_only)
    opt = MeZOAdam(cfg)
    params = jax.tree_util.tree_map(jnp.zeros_like, t)
    state = opt.init(params, seed=7)
    step = jax.jit(opt.step_fn(loss_fn))
    for _ in range(steps):
        params, state, m = step(params, state, None)
    return params, loss_fn


def test_recomputed_matches_materialized():
    """With window >= steps the truncation error is zero up to bias-correction
    fp noise."""
    p_mat, _ = setup(materialized=True, steps=12)
    p_rec, _ = setup(materialized=False, window=32, steps=12)
    assert tree_max_abs_diff(p_mat, p_rec) < 1e-4


def test_momentum_only_matches():
    p_mat, _ = setup(materialized=True, momentum_only=True, steps=10)
    p_rec, _ = setup(materialized=False, momentum_only=True, window=32, steps=10)
    assert tree_max_abs_diff(p_mat, p_rec) < 1e-4


def test_mezo_adam_descends():
    params, loss_fn = setup(materialized=False, window=16, steps=300, lr=3e-2)
    key = jax.random.PRNGKey(0)
    t = jax.random.normal(key, (16,))
    l0 = 0.5 * float(jnp.sum(t ** 2))
    assert float(loss_fn(params, None)) < 0.5 * l0
